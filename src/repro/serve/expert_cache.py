"""Expert-weight paging: bounded device residency for MoE expert weights.

The software analogue of Edge-MoE's DDR expert streaming (§IV-D): device
memory holds only a bounded set of expert weights (a configurable fraction
of E); the rest live in host memory and are paged in on demand.  Three
pieces:

  * ``ExpertUsage``   — per-task EMA of the router's per-expert dispatch
    counts (exported by ``core/moe.py`` via ``return_stats`` /
    ``routing.dispatch_counts``).  This is the prediction signal: the
    paper's task-level sparsity means each task concentrates its routing
    mass on a stable expert subset, so usage history predicts the next
    batch's working set.
  * ``ExpertCache``   — the residency manager: fixed device slot arrays
    (R stacked weight tensors per projection), LRU eviction, demand paging
    with hit/miss/byte accounting, and usage-driven prefetch.
  * ``PagedMoE``      — a serve-time MoE layer that routes on device, pages
    the needed experts, and runs the expert FFN in *waves* of at most R
    resident experts.  Wave outputs land in a per-(token, slot) row buffer
    (disjoint across waves) and the final gate-weighted combine sums the
    rows in exactly the same order as ``core.moe.apply_moe`` — the paged
    forward is **bit-exact** with the all-resident forward (tested).
  * ``ShardedExpertCache`` — the expert-parallel form: experts are
    partitioned over a mesh axis (``model``), each shard owns a bounded
    slot bank for ITS experts only, and the device store is one stacked
    ``(shards, R, ...)`` array sharded over that axis.  A fixed per-device
    slot budget therefore scales total resident experts linearly with the
    shard count — the distributed inversion of the paper's "load each
    expert once": experts stay put and the ``(E, C, d)`` dispatch buffers
    move through the all-to-all that GSPMD derives from the one-hot
    dispatch einsums.  ``PagedMoE(mesh=...)`` switches to this path; it
    stays bit-exact with the single-device forward (tested at mesh 2/4).
"""

from __future__ import annotations

from collections import OrderedDict, deque
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import routing as R
from repro.core.moe import (MoEConfig, _expert_ffn, expert_param_names,
                            group_shape)
from repro.core.unified_linear import unified_linear
from repro.dist.sharding import ep_dispatch_sharding
from repro.factor import FactoredTensor, is_factored
from repro.quant import QTensor, is_qtensor
from repro.serve.placement import PlacementPlan, PlacementPolicy, get_policy
from repro.serve.transfer import Transfer

__all__ = ["ExpertUsage", "ExpertCache", "ShardedExpertCache", "PagedMoE"]

# how many truncation-dropped prefetch ids each cache retains as evidence
# (bounded so a long-running server cannot grow the list without limit)
PREFETCH_DROPPED_KEEP = 64


def _per_expert_bytes(host: dict) -> int:
    """Device bytes one expert occupies across the PAGED weight leaves —
    the unit of both paging accounting and byte-budget residency sizing.
    Pinned leaves (a factored layer's shared basis) are deliberately
    absent from ``host``: they are resident once, not per expert, and are
    accounted separately (:func:`_pinned_bytes`)."""
    return sum(int(w[0].nbytes) for w in host.values())


def _pinned_bytes(pinned: Optional[dict]) -> int:
    """Device bytes of the always-resident (never paged) leaves."""
    return sum(int(v.nbytes) for v in (pinned or {}).values())


class ExpertUsage:
    """Per-task EMA + cumulative totals of per-expert dispatch counts."""

    def __init__(self, num_experts: int, num_tasks: int = 1,
                 decay: float = 0.9):
        self.num_experts = num_experts
        self.num_tasks = max(1, num_tasks)
        self.decay = decay
        self.ema = np.zeros((self.num_tasks, num_experts), np.float64)
        self.totals = np.zeros((self.num_tasks, num_experts), np.int64)

    def update(self, counts, task_id: int = 0) -> None:
        c = np.asarray(counts, np.float64).reshape(-1)
        if c.size != self.num_experts:
            raise ValueError(f"counts size {c.size} != E={self.num_experts}")
        self.ema[task_id] = self.decay * self.ema[task_id] \
            + (1.0 - self.decay) * c
        self.totals[task_id] += c.astype(np.int64)

    def hot(self, k: int, task_id: Optional[int] = None) -> list[int]:
        """Top-k expert ids by EMA usage (one task, or summed over tasks).

        Ties break by expert id, EXPLICITLY (lexsort keys, not argsort
        order): prefetch ranking and elastic placement both consume this
        list, and both must be deterministic across platforms."""
        v = self.ema[task_id] if task_id is not None else self.ema.sum(axis=0)
        order = np.lexsort((np.arange(v.size), -v))
        return [int(e) for e in order[:k]]

    def task_overlap(self) -> float:
        """Mean pairwise cosine similarity of per-task usage — low values
        are the paper's task-level sparsity (disjoint working sets)."""
        if self.num_tasks < 2:
            return 1.0
        sims = []
        for a in range(self.num_tasks):
            for b in range(a + 1, self.num_tasks):
                u, v = self.totals[a].astype(float), self.totals[b].astype(float)
                n = np.linalg.norm(u) * np.linalg.norm(v)
                sims.append(float(u @ v / n) if n else 1.0)
        return float(np.mean(sims))


class ExpertCache:
    """Bounded device slots over a host-resident (E, ...) weight store.

    ``host``: {name: (E, ...) np.ndarray} — the per-expert weight tensors
    (``expert_param_names`` order).  ``max_resident`` slots are allocated on
    device; ``ensure`` demand-pages, ``prefetch`` warms without touching the
    demand hit/miss counters.

    With a ``transfer_engine`` (``serve/transfer.py``) the cache pages
    asynchronously: ``prefetch_async`` *submits* non-blocking host→device
    copies and returns immediately (the slot is reserved and the expert
    tracked in-flight), ``ensure`` *fences* any in-flight member before
    the caller dereferences it, and demand misses submit-then-fence so
    even unpredicted paging flows through the same accounted stream.
    Evicting an in-flight expert cancels its transfer — the slot's next
    occupant can never be clobbered by a late completion (double-buffer
    slot-reuse ordering; tested under adversarial completion schedules).
    Without an engine every code path is the PR-2 synchronous one,
    unchanged.
    """

    def __init__(self, host: dict[str, np.ndarray], max_resident: int,
                 usage: Optional[ExpertUsage] = None,
                 write_cb: Optional[Callable[[int, dict], None]] = None,
                 transfer_engine=None, label: str = "cache",
                 pinned: Optional[dict] = None,
                 policy: Optional[PlacementPolicy] = None):
        if not host:
            raise ValueError("empty expert weight store")
        # all residency DECISIONS (victim pick, prefetch ranking) live in
        # the policy; this class is mechanism — slots, copies, commits
        self.policy = policy if policy is not None else get_policy("static")
        # pinned leaves (e.g. a factored layer's shared basis) are put on
        # device ONCE here and never enter the slot store, LRU, or paging
        # byte accounting — they have no per-expert axis
        pinned = pinned or {}
        clash = set(pinned) & set(host)
        if clash:
            raise ValueError(f"leaves both pinned and paged: {sorted(clash)}")
        self.pinned = {n: jnp.asarray(v) for n, v in pinned.items()}
        self.pinned_bytes = _pinned_bytes(self.pinned)
        # transfer keys are (label, expert) — stable and test-addressable
        # (a FakeTransferEngine ``schedule`` can name them ahead of time)
        self.label = label
        self.names = tuple(host)
        self.num_experts = next(iter(host.values())).shape[0]
        for n, w in host.items():
            if w.shape[0] != self.num_experts:
                raise ValueError(f"{n}: leading dim {w.shape[0]} != E")
        self.max_resident = max(1, min(int(max_resident), self.num_experts))
        self.host = {n: np.asarray(w) for n, w in host.items()}
        self.usage = usage
        self._write_cb = write_cb
        if write_cb is None:
            # device slot store: one stacked (R, ...) tensor per weight name
            self.slots = {
                n: jnp.zeros((self.max_resident,) + w.shape[1:], w.dtype)
                for n, w in self.host.items()
            }
            self._write = jax.jit(
                lambda slots, new, r: {
                    n: slots[n].at[r].set(new[n]) for n in slots},
                donate_argnums=(0,))
            # batched variant: one donated store update for a whole fence
            # wave.  While compute holds the slots buffers the runtime
            # cannot donate in place and falls back to a copy — paying
            # that once per wave instead of once per expert is what keeps
            # the async stream cheaper than it hides.  The per-expert
            # rows go in as separate args (no host-side stack): the sets
            # fuse into one scatter-like update inside the jit

            def _write_many(slots, idx, *rows):
                for i, r in enumerate(rows):
                    slots = {n: slots[n].at[idx[i]].set(r[n])
                             for n in slots}
                return slots

            self._write_many = jax.jit(_write_many, donate_argnums=(0,))
            # full-overwrite variant: a fence wave that replaces EVERY
            # slot (the steady state when wave size == R) builds the new
            # store straight from the payload rows — no read of, or
            # donation dependency on, the old buffers, so the commit
            # never has to wait for (or copy around) in-flight compute
            # that still holds them
            self._write_full = jax.jit(
                lambda *rows: {
                    n: jnp.stack([r[n] for r in rows])
                    for n in self.names})
        else:
            # bookkeeping-only mode: the slot store lives elsewhere (one
            # shard bank of a ShardedExpertCache); page-ins go through the
            # callback, which writes host rows into the external store
            self.slots = None
            self._write = None
            self._write_many = None
            self._write_full = None
        self._slot_expert = [-1] * self.max_resident     # slot -> expert id
        self._lru: OrderedDict[int, int] = OrderedDict()  # expert -> slot
        self.engine = transfer_engine
        # expert -> (slot, Transfer): slot reserved, copy not yet committed
        self._inflight: dict[int, tuple[int, Transfer]] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.bytes_paged = 0
        self.async_prefetches = 0     # transfers submitted by prefetch_async
        self.inflight_joins = 0       # in-flight transfers fenced by ensure
        self.async_cancelled = 0      # in-flight prefetches killed by evict
        self.prefetch_truncated = 0       # ids dropped by over-long prefetch
        # dropped ids ACCUMULATE (bounded) — a multi-wave run must not lose
        # earlier truncation evidence to the latest prefetch call
        self.prefetch_dropped: deque[int] = deque(maxlen=PREFETCH_DROPPED_KEEP)
        self._expert_bytes = _per_expert_bytes(self.host)

    # -------------------------------------------------------------- state

    @property
    def resident(self) -> list[int]:
        """Experts holding a slot — committed OR reserved by an in-flight
        prefetch (wave planning treats an arriving expert as warm; its
        copy is fenced before any dereference)."""
        return [e for e in self._slot_expert if e >= 0]

    @property
    def inflight(self) -> list[int]:
        """Experts whose copy has been submitted but not yet fenced."""
        return list(self._inflight)

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 1.0

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = self.bytes_paged = 0
        self.async_prefetches = self.inflight_joins = 0
        self.async_cancelled = 0
        self.prefetch_truncated = 0
        self.prefetch_dropped.clear()

    def stats(self) -> dict[str, Any]:
        out = {
            "hits": self.hits, "misses": self.misses,
            "evictions": self.evictions, "bytes_paged": self.bytes_paged,
            "hit_rate": self.hit_rate,
            "max_resident": self.max_resident,
            "resident_fraction": self.max_resident / self.num_experts,
            "prefetch_truncated": self.prefetch_truncated,
            "prefetch_dropped": list(self.prefetch_dropped),
            # heterogeneous residency accounting: paged bytes scale with
            # the slot count, pinned bytes are paid once (factored basis)
            "paged_expert_bytes": self._expert_bytes,
            "pinned_bytes": self.pinned_bytes,
        }
        if self.engine is not None:
            out.update({
                "async_prefetches": self.async_prefetches,
                "inflight_joins": self.inflight_joins,
                "async_cancelled": self.async_cancelled,
                "inflight": len(self._inflight),
                "stall_s": self.engine.stats.stall_s,
                "overlap_ratio": self.engine.stats.overlap_ratio,
            })
        return out

    # ------------------------------------------------------------- paging

    def _reserve_slot(self, pinned: set[int]) -> int:
        """Claim a slot for a new occupant: first free slot, else evict the
        policy's victim (LRU-not-in-working-set for every stock policy).
        Evicting an expert whose prefetch is still in flight CANCELS the
        transfer — the copy never committed, so the slot's next occupant
        cannot be clobbered by a late completion (the double-buffer
        slot-reuse ordering contract)."""
        free = [s for s, e in enumerate(self._slot_expert) if e < 0]
        if free:
            return free[0]
        victim = self.policy.victim(self._lru, pinned)
        slot = self._lru.pop(victim)
        self._slot_expert[slot] = -1
        self.evictions += 1
        vt = self._inflight.pop(victim, None)
        if vt is not None:
            self.engine.cancel(vt[1])
            self.async_cancelled += 1
        return slot

    def _commit(self, expert: int, slot: int, arrays: dict) -> None:
        """Land ``arrays`` (host or already-device leaves) in ``slot`` and
        finish the residency bookkeeping."""
        if self._write_cb is not None:
            self._write_cb(slot, arrays)
        else:
            dev = {n: jax.device_put(v) for n, v in arrays.items()}
            self.slots = self._write(self.slots, dev, slot)
        self._slot_expert[slot] = expert
        self._lru[expert] = slot
        self.bytes_paged += self._expert_bytes

    def _host_rows(self, expert: int) -> dict[str, np.ndarray]:
        return {n: self.host[n][expert] for n in self.names}

    def _page_in(self, expert: int, pinned: set[int]) -> None:
        """Synchronous demand page-in (also the misprediction fallback:
        an expert nobody prefetched still pages correctly — through the
        engine when one is attached, so its stall is accounted)."""
        slot = self._reserve_slot(pinned)
        new = self._host_rows(expert)
        if self.engine is not None:
            tr = self.engine.submit((self.label, expert), new, tag="demand")
            new = self.engine.fence(tr)
        self._commit(expert, slot, new)

    def _submit_async(self, expert: int, pinned: set[int],
                      tag: str = "demand") -> Transfer:
        """Reserve a slot and start a non-blocking copy for ``expert``.
        The slot is RESERVED (``_slot_expert``/``_lru`` claim it so LRU
        ordering and wave planning see it coming) but the store is not
        touched until the transfer is fenced and committed."""
        slot = self._reserve_slot(pinned)
        tr = self.engine.submit((self.label, expert),
                                self._host_rows(expert), tag=tag)
        self._inflight[expert] = (slot, tr)
        self._slot_expert[slot] = expert
        self._lru[expert] = slot
        return tr

    def _join(self, expert: int) -> None:
        """Fence an in-flight transfer and commit it to its reserved slot.
        May raise ``TransferTimeout`` (a hung transport is loud, never a
        silent deadlock)."""
        slot, tr = self._inflight.pop(expert)
        payload = self.engine.fence(tr)
        self._commit(expert, slot, payload)
        self.inflight_joins += 1

    def _commit_batch(self, batch: list[tuple[int, int, dict]]) -> None:
        """Land a whole fence wave of ``(expert, slot, payload)`` in ONE
        donated store update.  Slots in a batch are distinct (each
        in-flight expert holds its own reservation), so the scatter is
        bit-identical to committing them one by one — it just pays the
        donate-while-compute-reads copy once instead of per expert."""
        if not batch:
            return
        if self._write_many is None or len(batch) == 1:
            for e, slot, payload in batch:
                self._commit(e, slot, payload)
            return
        # pad to the next power of two by REPEATING entry 0: batch sizes
        # vary per fence, and every distinct size is a fresh XLA compile
        # of the scatter — pow2 bucketing caps that at log2(R) variants.
        # A duplicated (slot, payload) pair writes identical values to
        # the same index, so the scatter result is unchanged
        k = len(batch)
        if k == self.max_resident:
            # every slot is being replaced: fresh store, old one dropped
            by_slot = sorted(batch, key=lambda t: t[1])
            self.slots = self._write_full(*(p for _, _, p in by_slot))
        else:
            full = batch + [batch[0]] * ((1 << (k - 1).bit_length()) - k)
            idx = jnp.asarray([s for _, s, _ in full], jnp.int32)
            self.slots = self._write_many(self.slots, idx,
                                          *(p for _, _, p in full))
        for e, slot, _ in batch:
            self._slot_expert[slot] = e
            self._lru[e] = slot
            self.bytes_paged += self._expert_bytes

    def ensure_submit(self, expert_ids, record: bool = True) -> list[int]:
        """Async first half of ``ensure``: submit copies for every missing
        id without fencing any — the per-expert transfers overlap each
        other and whatever compute is already in flight.  Returns the ids
        that must be fenced (``ensure_fence``) before dereferencing.
        Requires a transfer engine."""
        needed = self._check_working_set(expert_ids)
        pinned = set(needed)
        to_fence = []
        for e in needed:
            if e in self._inflight:
                self._lru.move_to_end(e)
                if record:
                    self.hits += 1     # prefetch predicted it; fence below
                to_fence.append(e)
            elif e in self._lru:
                self._lru.move_to_end(e)
                if record:
                    self.hits += 1
            else:
                if record:
                    self.misses += 1
                self._submit_async(e, pinned)
                to_fence.append(e)
        return to_fence

    def ensure_fence(self, expert_ids) -> None:
        """Fence+commit the in-flight members of ``expert_ids`` (the
        second half of the async ``ensure``).  Payloads are fenced one by
        one but committed as a single batched store write; if a fence
        raises (hung transport), everything fenced before it still
        commits — then the timeout propagates, loud."""
        batch: list[tuple[int, int, dict]] = []
        try:
            for e in expert_ids:
                e = int(e)
                if e in self._inflight:
                    slot, tr = self._inflight.pop(e)
                    payload = self.engine.fence(tr)
                    batch.append((e, slot, payload))
                    self.inflight_joins += 1
        finally:
            self._commit_batch(batch)

    def _check_working_set(self, expert_ids) -> list[int]:
        needed = list(dict.fromkeys(int(e) for e in expert_ids))
        if len(needed) > self.max_resident:
            raise ValueError(
                f"{len(needed)} experts needed at once but only "
                f"{self.max_resident} slots — page in waves")
        return needed

    def ensure(self, expert_ids, record: bool = True) -> None:
        """Make every id in ``expert_ids`` device-resident (≤ max_resident).

        With a transfer engine this is submit-all-then-fence-all, so the
        misses' copies overlap each other; in-flight prefetches are fenced
        (and counted as hits — the prediction converted demand paging into
        an already-flying copy).  Without an engine it is the synchronous
        PR-2 path, bit-for-bit."""
        if self.engine is not None:
            self.ensure_fence(self.ensure_submit(expert_ids, record=record))
            return
        needed = self._check_working_set(expert_ids)
        pinned = set(needed)
        for e in needed:
            if e in self._lru:
                self._lru.move_to_end(e)
                if record:
                    self.hits += 1
            else:
                if record:
                    self.misses += 1
                self._page_in(e, pinned)

    def _truncate_prefetch(self, expert_ids) -> list[int]:
        ids = list(dict.fromkeys(int(e) for e in expert_ids))
        keep, dropped = ids[: self.max_resident], ids[self.max_resident:]
        if dropped:
            self.prefetch_truncated += len(dropped)
            self.prefetch_dropped.extend(dropped)
        return keep

    def prefetch(self, expert_ids) -> None:
        """Warm residency (e.g. from ``ExpertUsage.hot``) without demand
        accounting — prefetched experts later hit in ``ensure``.

        A warm-up list longer than the slot count is truncated to the first
        ``max_resident`` (unique) ids; the tail is NOT silently dropped —
        the dropped count and ids ACCUMULATE in the cache stats
        (``prefetch_truncated`` / ``prefetch_dropped``, bounded deque)."""
        self.ensure(self._truncate_prefetch(expert_ids), record=False)

    def prefetch_async(self, expert_ids, tag: str = "prefetch") -> list[int]:
        """Router-lookahead warm-up: SUBMIT non-blocking copies for the
        given ids and return immediately (no fence — the copies ride
        behind whatever compute runs next; ``ensure`` fences them at the
        point of use).  Falls back to the synchronous ``prefetch`` when no
        engine is attached.  Returns the ids actually submitted."""
        if self.engine is None:
            self.prefetch(expert_ids)
            return []
        keep = self._truncate_prefetch(expert_ids)
        pinned = set(keep)
        submitted = []
        for e in keep:
            if e in self._lru:              # resident or already in flight
                self._lru.move_to_end(e)
                continue
            self._submit_async(e, pinned, tag=tag)
            self.async_prefetches += 1
            submitted.append(e)
        return submitted

    def drop(self, expert: int) -> bool:
        """Release ``expert``'s slot, if it holds one (an in-flight copy
        is cancelled).  This is a PLACEMENT drop — ownership moved to
        another shard — not a capacity eviction, so it does not touch the
        eviction counter.  Returns True when a slot was freed."""
        e = int(expert)
        slot = self._lru.pop(e, None)
        if slot is None:
            return False
        self._slot_expert[slot] = -1
        vt = self._inflight.pop(e, None)
        if vt is not None:
            self.engine.cancel(vt[1])
            self.async_cancelled += 1
        return True

    def fence_all(self) -> None:
        """Commit every outstanding in-flight transfer (a full barrier —
        e.g. before tearing the cache down or snapshotting the store)."""
        self.ensure_fence(list(self._inflight))

    def remap(self) -> np.ndarray:
        """(E,) int32: expert id -> device slot, ``-1`` for non-resident.

        The sentinel is deliberate: a non-resident id must never silently
        alias whatever expert happens to occupy slot 0.  Every dereference
        site masks (``PagedMoE`` wave fns select slot indices only where
        the wave mask holds) and the host-side wave loop asserts that all
        wave ids map to real slots before launching the compute.

        An in-flight (reserved, uncommitted) expert maps to its reserved
        slot, whose STORE content is stale until ``ensure`` fences it —
        callers must ensure() the ids they dereference first (the paged
        wave loop always does)."""
        m = np.full((self.num_experts,), -1, np.int32)
        for s, e in enumerate(self._slot_expert):
            if e >= 0:
                m[e] = s
        return m

    def replica_table(self) -> tuple[np.ndarray, np.ndarray]:
        """Replica-aware remap: ``(table, counts)`` where ``table`` is
        (E, W) int32 slot ids (−1 padded) and ``counts`` is (E,) int32
        resident-replica counts.  A single-device cache never replicates:
        W = 1 and counts is the residency indicator — the wave dispatch's
        ``position % counts`` load split degenerates to the identity."""
        remap = self.remap()
        return remap[:, None], (remap >= 0).astype(np.int32)


class ShardedExpertCache:
    """Expert-parallel residency: experts placed over a mesh axis by a
    :class:`~repro.serve.placement.plan.PlacementPlan`.

    Shard ``s`` of ``m`` holds a bounded bank of ``max_resident`` device
    slots and serves the experts the PLAN assigns it — under the default
    static plan that is the contiguous block ``[s*E/m, (s+1)*E/m)``,
    bit-for-bit the old modulo partition; an elastic plan may migrate a
    cold expert's home shard or replicate a hot expert across several.
    The device store is ONE stacked ``(m, R, ...)`` array per weight
    name, sharded over ``axis`` — shard s's bank physically lives on
    shard s, and a page-in writes only that shard's partition.
    Bookkeeping (LRU, hit/miss/bytes, prefetch-truncation accounting) is
    one :class:`ExpertCache` per shard in external-write mode, keyed by
    GLOBAL expert id (transfer keys are ``("shard<s>", expert)``), so the
    single-device semantics — including the ``-1`` non-resident sentinel —
    carry over per shard and an expert can hold a slot on several shards
    at once.

    A fixed per-device slot budget therefore holds ``m × R`` resident
    experts in aggregate: residency scales linearly with the shard count.
    Plan swaps (:meth:`set_plan`) happen between forwards: moved-away
    residency is dropped, new homes stream in through the transfer engine
    (tagged ``migrate``) behind the next forward's compute, and the
    generation counter guarantees no wave observes a half-applied plan.
    """

    def __init__(self, host: dict[str, np.ndarray], max_resident: int,
                 mesh, axis: str = "model",
                 usage: Optional[ExpertUsage] = None,
                 transfer_engine=None, pinned: Optional[dict] = None,
                 policy: Optional[PlacementPolicy] = None,
                 plan: Optional[PlacementPlan] = None):
        if not host:
            raise ValueError("empty expert weight store")
        self.mesh = mesh
        self.axis = axis
        self.engine = transfer_engine
        self.policy = policy if policy is not None else get_policy("static")
        # pinned leaves are REPLICATED over the mesh (every shard computes
        # its experts' waves against the same shared basis) — each device
        # pays the pinned bytes once, like the single-device cache
        pinned = pinned or {}
        clash = set(pinned) & set(host)
        if clash:
            raise ValueError(f"leaves both pinned and paged: {sorted(clash)}")
        self.pinned = {
            n: jax.device_put(jnp.asarray(v),
                              NamedSharding(mesh, P(*([None] * np.ndim(v)))))
            for n, v in pinned.items()
        }
        self.pinned_bytes = _pinned_bytes(self.pinned)
        m = int(mesh.shape[axis])
        self.num_shards = m
        self.num_experts = next(iter(host.values())).shape[0]
        if self.num_experts % m:
            raise ValueError(
                f"E={self.num_experts} does not divide the {m}-way "
                f"{axis!r} axis")
        self.e_local = self.num_experts // m
        self.plan = plan if plan is not None \
            else self.policy.initial_plan(self.num_experts, m)
        if (self.plan.num_experts, self.plan.num_shards) \
                != (self.num_experts, m):
            raise ValueError(
                f"plan is ({self.plan.num_experts} experts, "
                f"{self.plan.num_shards} shards); cache has "
                f"({self.num_experts}, {m})")
        # replica-table width is FIXED by the policy at construction (1
        # for static, m for elastic): later plan swaps must never change
        # a jit-traced shape.  A width-1 bank never holds more than the
        # shard's static share; a replicating bank may hold up to E.
        self.table_width = max(1, min(int(self.policy.table_width(m)), m))
        cap = self.e_local if self.table_width == 1 else self.num_experts
        self.max_resident = max(1, min(int(max_resident), cap))
        rs = self.max_resident
        self.names = tuple(host)
        self.usage = usage
        # per-shard routed-token load (replicated experts split theirs
        # evenly) — the imbalance evidence the elastic policy consumes
        self.shard_load = np.zeros(m, np.float64)
        self.plan_swaps = 0
        self.migrations = 0        # replica additions from plan swaps
        self.migration_drops = 0   # residency released by plan swaps
        self.replications = 0      # experts whose replica count grew
        # stacked sharded slot store: (m, R, ...) over the expert axis
        self.slots = {
            n: jax.device_put(
                jnp.zeros((m, rs) + w.shape[1:], w.dtype),
                NamedSharding(mesh, P(axis, *([None] * w.ndim))))
            for n, w in host.items()
        }
        out_sh = {n: a.sharding for n, a in self.slots.items()}
        self._write = jax.jit(
            lambda slots, new, s, r: {
                n: slots[n].at[s, r].set(new[n]) for n in slots},
            donate_argnums=(0,), out_shardings=out_sh)

        # every book sees the FULL host store and keys by GLOBAL expert
        # id — which experts a shard may page is the plan's decision, not
        # baked into the book's address space (the pre-placement code
        # sliced ``host`` here, freezing the modulo partition in)
        full = {n: np.asarray(w) for n, w in host.items()}

        def _book(s: int) -> ExpertCache:
            def write_cb(slot, new, _s=s):
                dev = {n: jax.device_put(v) for n, v in new.items()}
                self.slots = self._write(self.slots, dev,
                                         jnp.int32(_s), jnp.int32(slot))

            return ExpertCache(full, rs, write_cb=write_cb,
                               transfer_engine=transfer_engine,
                               label=f"shard{s}", policy=self.policy)

        self.books = [_book(s) for s in range(m)]
        self._expert_bytes = self.books[0]._expert_bytes

    # -------------------------------------------------------------- state

    @property
    def total_slots(self) -> int:
        return self.num_shards * self.max_resident

    def owner(self, expert: int) -> int:
        """Primary home shard of ``expert`` — the plan's call (static
        plan: ``expert // e_local``, the historical modulo map)."""
        return self.plan.owner(expert)

    @property
    def resident(self) -> list[int]:
        """Global ids holding a slot on ANY shard (deduplicated — a
        replicated expert is listed once)."""
        out: dict[int, None] = {}
        for book in self.books:
            out.update(dict.fromkeys(book.resident))
        return list(out)

    def _sum(self, attr: str) -> int:
        return sum(getattr(b, attr) for b in self.books)

    hits = property(lambda self: self._sum("hits"))
    misses = property(lambda self: self._sum("misses"))
    evictions = property(lambda self: self._sum("evictions"))
    bytes_paged = property(lambda self: self._sum("bytes_paged"))
    prefetch_truncated = property(
        lambda self: self._sum("prefetch_truncated"))

    @property
    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 1.0

    def reset_stats(self) -> None:
        for b in self.books:
            b.reset_stats()
        # placement event counters (plan_swaps/migrations/replications)
        # are CUMULATIVE — they describe the plan's history, not an
        # interval; only the per-interval load evidence resets
        self.shard_load[:] = 0.0

    def record_load(self, per_expert_counts) -> None:
        """Fold one forward's routed-token counts into the per-shard load
        ledger: an expert's tokens land on its plan shards (replicas
        split evenly — exactly how the wave dispatch splits them)."""
        c = np.asarray(per_expert_counts, np.float64).reshape(-1)
        for e in np.nonzero(c)[0]:
            shards = self.plan.shards_of(int(e))
            share = c[e] / len(shards)
            for s in shards:
                self.shard_load[s] += share

    def shard_load_imbalance(self) -> float:
        """max/mean of per-shard routed load (1.0 = perfectly even, m =
        everything on one shard); 0.0 before any load is recorded."""
        tot = float(self.shard_load.sum())
        if tot <= 0:
            return 0.0
        return float(self.shard_load.max() * self.num_shards / tot)

    def stats(self) -> dict[str, Any]:
        out = {
            "hits": self.hits, "misses": self.misses,
            "evictions": self.evictions, "bytes_paged": self.bytes_paged,
            "hit_rate": self.hit_rate,
            "max_resident": self.max_resident,       # per shard
            "num_shards": self.num_shards,
            "total_slots": self.total_slots,
            "resident_fraction": self.total_slots / self.num_experts,
            "prefetch_truncated": self.prefetch_truncated,
            "paged_expert_bytes": self._expert_bytes,
            "pinned_bytes": self.pinned_bytes,       # per device (replicated)
            "shard_load": [float(v) for v in self.shard_load],
            "shard_load_imbalance": self.shard_load_imbalance(),
            "placement": {
                "policy": self.policy.name,
                "generation": self.plan.generation,
                "plan_swaps": self.plan_swaps,
                "migrations": self.migrations,
                "migration_drops": self.migration_drops,
                "replications": self.replications,
                "max_replicas": self.plan.max_replicas,
                "table_width": self.table_width,
            },
        }
        if self.engine is not None:
            out.update({
                "async_prefetches": self._sum("async_prefetches"),
                "inflight_joins": self._sum("inflight_joins"),
                "async_cancelled": self._sum("async_cancelled"),
                "inflight": sum(len(b._inflight) for b in self.books),
                # ONE engine serves every shard's book: read its ledger
                # once here, not per book (no double counting)
                "stall_s": self.engine.stats.stall_s,
                "overlap_ratio": self.engine.stats.overlap_ratio,
                "transfer_tags": self.engine.stats.tags_dict(),
            })
        return out

    # ------------------------------------------------------------- paging

    def _by_shard(self, expert_ids) -> dict[int, list[int]]:
        """Fan global ids out to the plan's shards (GLOBAL ids per shard;
        a replicated expert appears in several shards' lists)."""
        by: dict[int, list[int]] = {}
        for e in expert_ids:
            for s in self.plan.shards_of(int(e)):
                by.setdefault(s, []).append(int(e))
        return by

    def ensure(self, expert_ids, record: bool = True) -> None:
        """Make every (global) id resident on its owning shard.

        With a transfer engine this is two phases — EVERY shard's missing
        copies are submitted before ANY is fenced, so the per-shard
        page-ins overlap each other (and the all-to-all dispatch of the
        wave already on the device): the wave stalls for the slowest
        shard's copy, not the sum of all shards' copies."""
        by = self._by_shard(expert_ids)
        if self.engine is not None:
            pending = {s: self.books[s].ensure_submit(local, record=record)
                       for s, local in by.items()}
            for s, fence_ids in pending.items():
                self.books[s].ensure_fence(fence_ids)
            return
        for s, local in by.items():
            self.books[s].ensure(local, record=record)

    def prefetch(self, expert_ids) -> None:
        """Warm each shard's bank with its share of ``expert_ids`` (global
        ids, hottest first); per-shard truncation is recorded."""
        for s, local in self._by_shard(expert_ids).items():
            self.books[s].prefetch(local)

    def prefetch_async(self, expert_ids, tag: str = "prefetch") -> list[int]:
        """Submit non-blocking copies of each shard's share of
        ``expert_ids``; returns the GLOBAL ids actually submitted (a
        replicated expert is listed once per submitting shard)."""
        submitted = []
        for s, ids in self._by_shard(expert_ids).items():
            submitted.extend(self.books[s].prefetch_async(ids, tag=tag))
        return submitted

    def fence_all(self) -> None:
        for b in self.books:
            b.fence_all()

    # ---------------------------------------------------------- placement

    def set_plan(self, new_plan: PlacementPlan) -> None:
        """Install a rebalanced plan ATOMICALLY between forwards.

        Residency on shards the new plan removed is dropped (in-flight
        copies cancelled — the double-buffer slot-reuse contract), and
        page-ins for newly assigned homes are submitted through the
        transfer engine tagged ``migrate``, so they stream behind the
        next forward's compute; without an engine the next wave's
        ``ensure`` demand-pages them.  Callers never see a half-applied
        plan: this method runs only between forwards, and the generation
        bump makes each swap observable exactly once.
        """
        if (new_plan.num_experts, new_plan.num_shards) \
                != (self.num_experts, self.num_shards):
            raise ValueError("plan shape does not match cache")
        if new_plan.generation <= self.plan.generation:
            raise ValueError(
                f"plan generation must advance: {new_plan.generation} <= "
                f"{self.plan.generation}")
        if new_plan.max_replicas > self.table_width:
            raise ValueError(
                f"plan replicates {new_plan.max_replicas}-way but the "
                f"replica table is {self.table_width} wide")
        old = self.plan
        added: dict[int, list[int]] = {}
        for e in range(self.num_experts):
            before = set(old.shards_of(e))
            after = set(new_plan.shards_of(e))
            for s in before - after:
                if self.books[s].drop(e):
                    self.migration_drops += 1
            for s in after - before:
                added.setdefault(s, []).append(e)
            if len(after) > len(before):
                self.replications += 1
        self.plan = new_plan
        self.plan_swaps += 1
        self.migrations += sum(len(v) for v in added.values())
        if self.engine is not None:
            for s, ids in added.items():
                self.books[s].prefetch_async(ids, tag="migrate")

    def remap(self) -> np.ndarray:
        """(E,) int32: expert id -> GLOBAL slot index ``shard*R + slot``
        of the PRIMARY resident replica, in the flattened ``(m*R, ...)``
        view of the stacked store; ``-1`` for non-resident (same sentinel
        contract as ``ExpertCache``)."""
        table, counts = self.replica_table()
        return np.where(counts > 0, table[:, 0], -1).astype(np.int32)

    def replica_table(self) -> tuple[np.ndarray, np.ndarray]:
        """Replica-aware remap: ``(table, counts)``.

        ``table`` is (E, W) int32 — resident replicas' global slot ids
        ``shard*R + slot`` in plan order (primary first), −1 padded;
        ``counts`` is (E,) int32 resident-replica counts.  The wave
        dispatch splits an expert's tokens round-robin over its first
        ``counts[e]`` columns (``position % counts``) — with one replica
        everywhere this is exactly the historical ``remap()`` indexing.
        """
        books = [b.remap() for b in self.books]
        table = np.full((self.num_experts, self.table_width), -1, np.int32)
        counts = np.zeros(self.num_experts, np.int32)
        for e in range(self.num_experts):
            k = 0
            for s in self.plan.shards_of(e):
                if k >= self.table_width:
                    break
                slot = books[s][e]
                if slot >= 0:
                    table[e, k] = s * self.max_resident + slot
                    k += 1
            counts[e] = k
        return table, counts


class PagedMoE:
    """Serve-time MoE layer with bounded expert residency.

    Call semantics match ``core.moe.apply_moe(params, cfg, x, task_id)``:
    returns ``(y, aux)`` — bit-exact with the all-resident grouped path.
    The expert FFN runs in waves of at most ``max_resident`` experts; each
    wave writes its tokens' output rows into a shared (token, slot) row
    buffer (waves touch disjoint rows), and the final combine applies the
    gate weights and sums the k slots per token in the same order as
    ``routing.combine`` — so splitting into waves never changes the
    floating-point result.
    """

    def __init__(self, params, cfg: MoEConfig,
                 resident_fraction: float = 0.5,
                 usage: Optional[ExpertUsage] = None,
                 usage_decay: float = 0.9,
                 budget_bytes: Optional[int] = None,
                 mesh=None, ep_axis: str = "model",
                 transfer_engine=None,
                 placement=None):
        if cfg.impl not in ("grouped", "onehot"):
            raise ValueError(
                "PagedMoE pages the grouped/onehot expert paths (ep_local "
                "keeps all experts resident — nothing to page)")
        self.cfg = cfg
        # expert-parallel mode: a mesh whose ep_axis has >1 shards switches
        # the cache to per-shard banks and the waves to the one-hot GSPMD
        # dispatch (all-to-all moves tokens; experts stay put)
        self.mesh = None
        self.ep_axis = ep_axis
        if mesh is not None and ep_axis in mesh.axis_names \
                and int(mesh.shape[ep_axis]) > 1:
            self.mesh = mesh
        names = expert_param_names(cfg)
        # quantized expert weights page as their packed leaves (<name>.q /
        # <name>.scale): the cache store stays plain arrays, and the wave
        # rebuilds QTensors from the device slots (``_slot_params``) so the
        # grouped GEMM dispatches the xla_int8 impl.  Packed residency is
        # the memory multiplier: ~4× (int8) / ~8× (int4) more experts fit
        # the same device budget.
        #
        # FACTORED expert weights split further: the shared basis is PINNED
        # (device-resident once, outside the slot store) and only the tiny
        # per-expert delta factors page (<name>.u / <name>.v, themselves
        # splitting into .q/.scale when the deltas are quantized).  The
        # wave rebuilds the FactoredTensor from pinned basis + slot deltas,
        # so the grouped GEMM dispatches the xla_factored impl — per-expert
        # paged bytes drop 10-100× and the byte budget buys residency at
        # the DELTA price.
        self._names = names
        self._qmeta: dict[str, tuple] = {}
        self._fmeta: dict[str, dict] = {}
        host: dict[str, np.ndarray] = {}
        pinned: dict[str, np.ndarray] = {}

        def _host_leaf(key: str, leaf):
            """Flatten one paged leaf (array or QTensor) into host entries;
            returns the QTensor rebuild meta (or None for plain arrays)."""
            if is_qtensor(leaf):
                host[key + ".q"] = np.asarray(leaf.q)
                host[key + ".scale"] = np.asarray(leaf.scale)
                return (leaf.bits, leaf.dtype, leaf.rows)
            host[key] = np.asarray(leaf)
            return None

        for n in names:
            wn = params[n]
            if is_factored(wn):
                pinned[n + ".basis"] = np.asarray(wn.basis)
                self._fmeta[n] = {
                    "kind": wn.kind, "dtype": wn.dtype,
                    "u": _host_leaf(n + ".u", wn.u),
                    "v": _host_leaf(n + ".v", wn.v),
                }
            elif is_qtensor(wn):
                self._qmeta[n] = _host_leaf(n, wn)
            else:
                host[n] = np.asarray(wn)
        per_expert = _per_expert_bytes(host)
        pinned_total = _pinned_bytes(pinned)
        shards = int(self.mesh.shape[ep_axis]) if self.mesh is not None else 1
        e_per_shard = cfg.num_experts // shards
        # residency decisions live in the placement policy: ``placement``
        # is a name ("static"/"lru"/"budget"/"elastic") or a constructed
        # PlacementPolicy.  A bare ``budget_bytes`` keeps its historical
        # meaning by resolving to the budget policy; an explicit policy
        # without its own budget inherits the argument.
        if isinstance(placement, PlacementPolicy):
            self.policy = placement
        elif placement in (None, "static") and budget_bytes is not None:
            self.policy = get_policy("budget", budget_bytes=budget_bytes)
        else:
            self.policy = get_policy(placement)
        if budget_bytes is not None and self.policy.budget_bytes is None:
            self.policy.budget_bytes = int(budget_bytes)
        # slot sizing is the policy's call too (extracted byte-budget /
        # fraction arithmetic): ≥ top_k on a single device so one wave can
        # always serve a token's full expert set; per-shard banks only
        # need ≥ 1 — waves accumulate into disjoint rows, so splitting
        # never hurts
        floor = cfg.top_k if shards == 1 else 1
        max_resident = self.policy.slots(
            per_expert_bytes=per_expert, pinned_bytes=pinned_total,
            experts_per_shard=e_per_shard,
            resident_fraction=resident_fraction, floor=floor)
        self.usage = usage or ExpertUsage(cfg.num_experts, cfg.num_tasks,
                                          decay=usage_decay)
        # async paging: with a transfer engine the cache double-buffers —
        # wave k+1's host→device copies are submitted while wave k
        # computes, and usage-driven prefetches become non-blocking
        self.engine = transfer_engine
        if self.mesh is not None:
            self.cache = ShardedExpertCache(host, max_resident, self.mesh,
                                            axis=ep_axis, usage=self.usage,
                                            transfer_engine=transfer_engine,
                                            pinned=pinned,
                                            policy=self.policy)
        else:
            self.cache = ExpertCache(host, max_resident, usage=self.usage,
                                     transfer_engine=transfer_engine,
                                     pinned=pinned, policy=self.policy)
        self._forwards = 0   # rebalance cadence counter (policy-driven)
        # per-wave record of the most recent forward (wave id, expert
        # count, lookahead submissions, fence stall) — the paged layer's
        # contribution to the serve-time stall/overlap reports
        self.last_timeline: list[dict] = []
        self.gate = jnp.asarray(params["gate"])
        gb = params.get("gate_bias")   # optional (tasks, E) logit bias
        self.gate_bias = None if gb is None else jnp.asarray(gb)
        self.shared = {k: params[k] for k in
                       ("shared_wg", "shared_wu", "shared_wd") if k in params}
        self._route_fn = None
        self._wave_fn = None
        self._finish_fn = None

    def _slot_params(self, slots, pinned):
        """Rebuild the per-expert params dict from device slot arrays,
        re-wrapping quantized leaves as QTensors and factored leaves as
        FactoredTensors (jit-safe: both are pytrees of the slot tracers;
        the factored basis comes from the PINNED store, not the slots)."""
        def leaf(key, qmeta):
            if qmeta is not None:
                bits, dt, rows = qmeta
                return QTensor(slots[key + ".q"], slots[key + ".scale"],
                               bits=bits, dtype=dt, rows=rows)
            return slots[key]

        out = {}
        for n in self._names:
            if n in self._fmeta:
                fm = self._fmeta[n]
                out[n] = FactoredTensor(pinned[n + ".basis"],
                                        leaf(n + ".u", fm["u"]),
                                        leaf(n + ".v", fm["v"]),
                                        kind=fm["kind"], dtype=fm["dtype"])
            elif n in self._qmeta:
                out[n] = leaf(n, self._qmeta[n])
            else:
                out[n] = slots[n]
        return out

    # ------------------------------------------------------- jitted stages

    def _build(self, g: int, capacity: int):
        cfg = self.cfg
        e, k = cfg.num_experts, cfg.top_k
        sharded = self.mesh is not None
        # flattened slot-bank size the wave fns index into: per-shard banks
        # concatenate to (m*R) global slots in the sharded mode
        rs = (self.cache.total_slots if sharded
              else self.cache.max_resident)

        has_bias = self.gate_bias is not None

        def route(gate_w, gate_b, groups, real):
            def per_group(xg, rm):
                logits = jnp.einsum("td,de->te", xg.astype(jnp.float32),
                                    gate_w)
                if has_bias:
                    logits = logits + gate_b.astype(jnp.float32)
                r = R.route(logits, k, capacity,
                            renormalize=cfg.renormalize)
                # pad rows are excluded from usage stats (as in apply_moe)
                stat_valid = r.valid & rm[:, None]
                counts = jnp.zeros((e,), jnp.int32).at[
                    r.expert.reshape(-1)].add(
                        stat_valid.reshape(-1).astype(jnp.int32))
                return r, counts
            return jax.vmap(per_group)(groups, real)

        mesh, axis = self.mesh, self.ep_axis

        def wave(groups, routing, slots, pinned, wave_mask,
                 rep_table, rep_counts, rows_acc):
            if sharded:
                # (m, R, ...) shard banks -> flat (m*R, ...) global slots;
                # the reshape keeps the expert dim shard-contiguous so the
                # store stays partitioned over the expert-parallel axis
                # (pinned leaves carry no expert axis — replicated as-is)
                slots = {n: a.reshape((rs,) + a.shape[2:])
                         for n, a in slots.items()}
            params_w = self._slot_params(slots, pinned)

            def per_group(xg, r, rows):
                in_wave = wave_mask[r.expert]          # (T, k) bool
                # load-split replica dispatch: an expert's tokens are
                # dealt round-robin over its resident replicas (identical
                # weights on different shards), and each replica sees a
                # DENSE position stream (position // reps) — bit-exact
                # per token because a GEMM row depends only on its own
                # inputs, and the one-replica case reduces to exactly the
                # historical remap indexing (reps == 1 → identity).
                reps = jnp.maximum(rep_counts[r.expert], 1)
                ridx = jnp.remainder(r.position, reps)
                # the table carries -1 for unfilled replica columns;
                # dereference ONLY where the wave mask holds (a forgotten
                # mask must never alias slot 0's expert — see
                # ExpertCache.remap)
                slot_idx = jnp.where(in_wave, rep_table[r.expert, ridx], 0)
                r_w = R.Routing(
                    expert=slot_idx.astype(jnp.int32), gate=r.gate,
                    position=r.position // reps,
                    valid=r.valid & in_wave,
                    probs=r.probs)
                if sharded:
                    # one-hot dispatch: under GSPMD the (rs, C, d) buffer
                    # sharded over the expert axis turns these einsums
                    # into the token all-to-all of expert parallelism
                    buf = R.dispatch_onehot(xg, r_w, rs, capacity)
                    buf = jax.lax.with_sharding_constraint(
                        buf, ep_dispatch_sharding(mesh, axis))
                else:
                    buf = R.dispatch(xg, r_w, rs, capacity)
                sizes = R.dispatch_counts(r_w, rs)
                out = _expert_ffn(params_w, cfg, buf, sizes)
                ef = r_w.expert.reshape(-1)
                pf = jnp.minimum(r_w.position.reshape(-1), capacity - 1)
                got = out[ef, pf]                      # (T*k, d)
                sel = (r_w.valid.reshape(-1))[:, None]
                return jnp.where(sel, got, rows)
            return jax.vmap(per_group)(groups, routing, rows_acc)

        def finish(routing, rows_acc, real):
            def per_group(r, rows, rm):
                # identical weighting + slot-sum order to routing.combine
                w = (r.gate.reshape(-1)
                     * r.valid.reshape(-1)).astype(rows.dtype)
                y = (rows * w[:, None]).reshape(g, k, -1).sum(axis=1)
                aux = R.load_balance_loss(r.probs, r.expert, e, mask=rm)
                return y, aux
            return jax.vmap(per_group)(routing, rows_acc, real)

        self._route_fn = jax.jit(route)
        self._wave_fn = jax.jit(wave, donate_argnums=(7,))
        self._finish_fn = jax.jit(finish)
        self._built_for = (g, capacity)

    # ------------------------------------------------------------- forward

    def __call__(self, x: jax.Array, task_id: int = 0):
        cfg = self.cfg
        orig_shape = x.shape
        d = x.shape[-1]
        flat = x.reshape(-1, d)
        t_total = flat.shape[0]
        g, t_pad = group_shape(t_total, cfg.group_size)
        if t_pad != t_total:
            flat = jnp.concatenate(
                [flat, jnp.zeros((t_pad - t_total, d), flat.dtype)])
        real = (jnp.arange(t_pad) < t_total).reshape(t_pad // g, g)
        groups = flat.reshape(t_pad // g, g, d)
        capacity = cfg.capacity(g)
        if getattr(self, "_built_for", None) != (g, capacity):
            self._build(g, capacity)

        gate_w = self.gate
        if gate_w.ndim == 3:
            gate_w = gate_w[int(task_id)]
        gate_b = self.gate_bias
        if gate_b is not None and gate_b.ndim == 2:
            gate_b = gate_b[int(task_id)]
        if gate_b is None:
            gate_b = jnp.zeros((cfg.num_experts,), jnp.float32)
        routing, counts = self._route_fn(gate_w, gate_b, groups, real)

        counts_np = np.asarray(counts.sum(axis=0))
        self.usage.update(counts_np, task_id)
        if self.mesh is not None:
            # per-shard load evidence for the elastic policy (and the
            # imbalance numbers in stats()) — recorded under the CURRENT
            # plan, i.e. where this forward's tokens actually go
            self.cache.record_load(counts_np)
        needed = [int(i) for i in np.nonzero(counts_np)[0]]
        # wave order: already-resident experts first, so warm residency
        # (prefetch or the previous batch) turns into demand hits
        res = set(self.cache.resident)
        needed.sort(key=lambda i: (i not in res, i))

        n = groups.shape[0]
        rows = jnp.zeros((n, g * cfg.top_k, d), groups.dtype)
        waves = self._plan_waves(needed)
        eng = self.engine
        timeline: list[dict] = []
        for k, wave_ids in enumerate(waves):
            stall0 = eng.stats.stall_s if eng is not None else 0.0
            # fence point: everything this wave dereferences must have
            # landed — in-flight lookahead copies commit here, anything
            # mispredicted demand-pages (correctness never depends on
            # prediction quality)
            self.cache.ensure(wave_ids)
            table, rep_counts = self.cache.replica_table()
            # masking contract: every id this wave dereferences must be
            # resident on at least one of its plan shards (the table
            # carries -1 sentinels for everything else)
            assert (rep_counts[wave_ids] >= 1).all(), \
                f"wave ids {wave_ids} not all resident: " \
                f"{rep_counts[wave_ids]}"
            mask = np.zeros((cfg.num_experts,), bool)
            mask[wave_ids] = True
            rows = self._wave_fn(groups, routing, self.cache.slots,
                                 self.cache.pinned, jnp.asarray(mask),
                                 jnp.asarray(table),
                                 jnp.asarray(rep_counts), rows)
            prefetched: list[int] = []
            if eng is not None:
                if k + 1 < len(waves):
                    # router lookahead inside the batch: the wave launch
                    # above is non-blocking, so wave k+1's copies are
                    # submitted NOW and ride behind wave k's compute —
                    # the double-buffer. Evicted slots are safe to retarget
                    # (commits happen only at the next fence point).
                    prefetched = self.cache.prefetch_async(waves[k + 1])
                eng.on_wave()   # virtual-clock transports model the
                #                 wave's compute time passing here
            timeline.append({
                "wave": k, "experts": len(wave_ids),
                "lookahead_submitted": len(prefetched),
                "stall_s": (eng.stats.stall_s - stall0) if eng is not None
                else 0.0,
            })
        self.last_timeline = timeline
        # rebalance point: ALL of this forward's waves have launched, the
        # next forward has not started — the only place a plan may swap.
        # Migration page-ins submitted here stream behind the combine and
        # the trunk layers that follow (tagged "migrate" in the ledger).
        self._maybe_rebalance()
        y, aux = self._finish_fn(routing, rows, real)
        y = y.reshape(-1, d)[:t_total].reshape(orig_shape).astype(x.dtype)

        if cfg.num_shared_experts:
            gshared = unified_linear(x, self.shared["shared_wg"],
                                     activation="silu")
            ushared = unified_linear(x, self.shared["shared_wu"])
            y = y + unified_linear((gshared * ushared).astype(x.dtype),
                                   self.shared["shared_wd"])
        return y, aux.mean()

    def _plan_waves(self, needed: list[int]) -> list[list[int]]:
        """Chunk the needed experts into residency-bounded waves.

        Single device: consecutive chunks of ``max_resident``.  Expert-
        parallel: first-fit against every shard's bank — an expert joins
        the earliest wave in which ALL of its plan shards still have a
        free slot (a replicated expert claims one slot per shard).  All
        shards compute concurrently, so the wave count is the max
        per-shard slot pressure, not the global count (the linear-scaling
        win); for single-replica plans this is exactly the per-shard
        chunking the static path always did."""
        rs = self.cache.max_resident
        if self.mesh is None:
            return [needed[i:i + rs] for i in range(0, len(needed), rs)]
        plan = self.cache.plan
        waves: list[list[int]] = []
        loads: list[np.ndarray] = []
        for e in needed:   # first-fit keeps the resident-first order
            shards = plan.shards_of(e)
            w = 0
            while True:
                if w == len(waves):
                    waves.append([])
                    loads.append(np.zeros(self.cache.num_shards, np.int64))
                if all(loads[w][s] < rs for s in shards):
                    waves[w].append(e)
                    for s in shards:
                        loads[w][s] += 1
                    break
                w += 1
        return waves

    def _maybe_rebalance(self) -> None:
        """Consult the placement policy between forwards (its cadence):
        an accepted proposal swaps the plan atomically via ``set_plan``."""
        if self.mesh is None:
            return
        every = getattr(self.policy, "rebalance_every", 0)
        self._forwards += 1
        if not every or self._forwards % every:
            return
        new = self.policy.update(self.cache.plan, self.usage,
                                 self.cache.shard_load,
                                 slots_per_shard=self.cache.max_resident)
        if new is not None:
            self.cache.set_plan(new)

    def predict(self, task_id: Optional[int] = None) -> list[int]:
        """Router-lookahead prediction: the next batch's expert working
        set, hottest first, from the per-task usage EMA (task-level
        sparsity makes this stable — the paper's §IV-F premise).  The
        ranking itself is the placement policy's call — the scheduler's
        cross-quantum lookahead and the per-batch prefetch both consume
        the plan through this one interface."""
        budget = (self.cache.total_slots if self.mesh is not None
                  else self.cache.max_resident)
        return self.policy.prefetch_ranking(self.usage, budget, task_id)

    def prefetch(self, task_id: Optional[int] = None) -> None:
        """Warm the device slots with the usage-EMA-hot experts for a task —
        called by the scheduler ahead of a task-bucket switch.  In the
        expert-parallel mode every shard warms its own bank with its share
        of the hot set (aggregate residency = shards × bank size).

        With a transfer engine the warm-up is NON-BLOCKING: copies are
        submitted and ride behind whatever computes next (the dense trunk
        blocks ahead of this layer, or the previous task's tail); the
        first wave that needs them fences."""
        hot = self.predict(task_id)
        if self.engine is not None:
            self.cache.prefetch_async(hot)
        else:
            self.cache.prefetch(hot)
