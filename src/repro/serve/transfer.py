"""Asynchronous host→device expert-weight transfers with explicit fences.

Edge-MoE's premise is that expert weights *stream* past a small fast
memory without ever stalling the compute pipeline (§IV-D).  The serving
analogue is a **copy stream**: host→device page-ins are *submitted*
non-blocking the moment the router makes the next wave predictable, run
while the current wave computes, and are *fenced* (waited on) only at the
point the weights are actually dereferenced.  This module is that copy
stream, factored so the paging policy in ``serve/expert_cache.py`` never
touches a clock or a thread directly:

  * :class:`TransferEngine` — the production transport.  ``submit`` hands
    the host arrays to a small worker pool that runs ``jax.device_put``
    off the dispatch thread (JAX is thread-safe for transfers; this is
    the software stand-in for a DMA copy queue), returning a
    :class:`Transfer` handle immediately.  ``fence`` blocks until the
    copy has landed, and *accounts the block*: time spent inside a fence
    is ``stall_s`` (the copy was NOT hidden), time between submit and an
    already-complete fence is ``hidden_s`` (the copy rode behind
    compute).  ``overlap_ratio = hidden_s / (hidden_s + stall_s)`` is the
    headline number: 1.0 means every byte streamed behind compute, 0.0
    means fully synchronous demand paging.
  * :class:`FakeTransferEngine` — the deterministic test transport.  Same
    API, but time is a **virtual clock** the test owns: every transfer
    completes ``latency_s`` after submit (per-key overrides via
    ``schedule``), ``advance()`` models compute happening while copies
    fly, ``complete()`` force-finishes a specific transfer, and a
    ``None`` latency is a *hung* link — fencing it raises
    :class:`TransferTimeout` instead of deadlocking.  Values are exact
    (the host arrays are materialized at fence time), so adversarial
    completion orders can only break *bookkeeping*, which is precisely
    what the stall-injection suite hunts.
  * :class:`TransferStats` — the shared ledger both engines fill in and
    every ``stats()``/benchmark artifact reads (``stall_s``,
    ``overlap_ratio``, fence/cancel/byte counters).

Contract highlights (enforced by ``tests/test_async_paging.py``):

  * a fence returns the payload exactly once; fencing twice is an error;
  * ``cancel`` drops an in-flight transfer (its bytes are accounted as
    ``bytes_cancelled``, never as paged) — the caller uses this when an
    eviction retargets a slot whose prefetch never landed;
  * timeouts are LOUD: a transfer that cannot complete raises
    :class:`TransferTimeout` with the transfer key in the message.
"""

from __future__ import annotations

import math
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from concurrent.futures import TimeoutError as _FutureTimeout
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

__all__ = ["Transfer", "TransferStats", "TransferEngine",
           "FakeTransferEngine", "TransferTimeout"]


class TransferTimeout(RuntimeError):
    """A fenced transfer did not complete within the engine timeout."""


@dataclass
class TransferStats:
    """Ledger of copy-stream activity, shared by both transports.

    ``stall_s`` is time a fence spent *blocked* (the copy was on the
    critical path); ``hidden_s`` is submit→completion time that fences
    did NOT have to wait for (the copy overlapped compute).  Demand
    page-ins fence immediately after submit, so they contribute almost
    pure stall; well-predicted prefetches contribute almost pure hidden
    time.
    """

    submitted: int = 0
    fenced: int = 0
    fences_ready: int = 0        # fence found the copy already complete
    fences_blocked: int = 0      # fence had to wait
    cancelled: int = 0
    timeouts: int = 0
    bytes_submitted: int = 0
    bytes_cancelled: int = 0
    stall_s: float = 0.0
    hidden_s: float = 0.0
    # per-tag sub-ledgers ("demand" / "prefetch" / "migrate" / ...): the
    # placement benchmark reads tags["migrate"]["overlap_ratio"] to prove
    # rebalancing page-ins rode behind compute instead of stalling it
    tags: dict = field(default_factory=dict)

    def _tag(self, tag: str) -> dict:
        return self.tags.setdefault(tag, {
            "submitted": 0, "fenced": 0, "cancelled": 0,
            "stall_s": 0.0, "hidden_s": 0.0})

    def note_submit(self, tag: str) -> None:
        self._tag(tag)["submitted"] += 1

    def note_cancel(self, tag: str) -> None:
        self._tag(tag)["cancelled"] += 1

    def note_fence(self, tag: str, stall_s: float, hidden_s: float) -> None:
        d = self._tag(tag)
        d["fenced"] += 1
        d["stall_s"] += stall_s
        d["hidden_s"] += hidden_s

    def tags_dict(self) -> dict[str, Any]:
        out = {}
        for tag, d in self.tags.items():
            tot = d["stall_s"] + d["hidden_s"]
            out[tag] = dict(d, overlap_ratio=(
                d["hidden_s"] / tot if tot > 0 else 1.0))
        return out

    @property
    def active_s(self) -> float:
        """Total transfer time observed (hidden + stalled)."""
        return self.stall_s + self.hidden_s

    @property
    def overlap_ratio(self) -> float:
        """Fraction of transfer time hidden behind compute.  1.0 when no
        transfers happened (nothing to hide = nothing stalled)."""
        tot = self.active_s
        return self.hidden_s / tot if tot > 0 else 1.0

    def as_dict(self) -> dict[str, Any]:
        return {
            "submitted": self.submitted, "fenced": self.fenced,
            "fences_ready": self.fences_ready,
            "fences_blocked": self.fences_blocked,
            "cancelled": self.cancelled, "timeouts": self.timeouts,
            "bytes_submitted": self.bytes_submitted,
            "bytes_cancelled": self.bytes_cancelled,
            "stall_s": self.stall_s, "hidden_s": self.hidden_s,
            "overlap_ratio": self.overlap_ratio,
            "tags": self.tags_dict(),
        }

    def reset(self) -> None:
        for f in ("submitted", "fenced", "fences_ready", "fences_blocked",
                  "cancelled", "timeouts", "bytes_submitted",
                  "bytes_cancelled"):
            setattr(self, f, 0)
        self.stall_s = self.hidden_s = 0.0
        self.tags.clear()


class Transfer:
    """Handle for one in-flight host→device copy (one expert's leaves)."""

    __slots__ = ("key", "nbytes", "t_submit", "done", "cancelled",
                 "_payload", "_future", "ready_at", "tag")

    def __init__(self, key: Any, nbytes: int, t_submit: float,
                 tag: str = "page"):
        self.key = key
        self.nbytes = int(nbytes)
        self.t_submit = float(t_submit)
        self.tag = str(tag)
        self.done = False           # fenced (payload handed out)
        self.cancelled = False
        self._payload: Optional[dict] = None
        self._future = None         # real engine: worker-pool future
        self.ready_at: float = 0.0  # fake engine: virtual completion time

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        state = ("cancelled" if self.cancelled
                 else "done" if self.done else "inflight")
        return f"Transfer({self.key!r}, {self.nbytes}B, {state})"


def _nbytes(arrays: dict) -> int:
    return sum(int(np.asarray(a).nbytes) if not hasattr(a, "nbytes")
               else int(a.nbytes) for a in arrays.values())


class TransferEngine:
    """Production copy stream: worker-threaded ``jax.device_put``.

    ``submit`` enqueues the copy on a small thread pool and returns a
    handle immediately — the calling (dispatch) thread keeps launching
    compute while the workers move bytes.  ``fence`` joins the worker
    future and then blocks on the device arrays themselves
    (``block_until_ready``), so a returned payload is guaranteed landed.
    ``timeout_s`` bounds a fence; exceeding it raises
    :class:`TransferTimeout` (a hung transport must be loud, never a
    deadlock).

    The engine is intentionally policy-free: it neither knows about
    experts nor slots.  Keys are opaque and only used for error messages
    and the fake engine's ``schedule``/``complete`` hooks.
    """

    def __init__(self, workers: int = 2, timeout_s: Optional[float] = 60.0,
                 clock: Callable[[], float] = time.perf_counter):
        self._workers = max(1, int(workers))
        self._pool = ThreadPoolExecutor(
            max_workers=self._workers,
            thread_name_prefix="transfer-engine")
        self.timeout_s = timeout_s
        self._clock = clock
        self.stats = TransferStats()
        self._lock = threading.Lock()

    def now(self) -> float:
        return self._clock()

    # ------------------------------------------------------------ stream

    def submit(self, key: Any, arrays: dict, tag: str = "page") -> Transfer:
        """Begin a non-blocking host→device copy of ``arrays`` (a dict of
        host ndarrays).  Returns immediately.  ``tag`` labels the copy's
        purpose ("demand"/"prefetch"/"migrate") for the per-tag ledger."""
        t = Transfer(key, _nbytes(arrays), self.now(), tag=tag)
        # snapshot the host views: the worker must not race a caller that
        # mutates the host store after submit
        host = {n: np.asarray(a) for n, a in arrays.items()}
        t._future = self._pool.submit(
            lambda: {n: jax.device_put(a) for n, a in host.items()})
        with self._lock:
            self.stats.submitted += 1
            self.stats.bytes_submitted += t.nbytes
            self.stats.note_submit(t.tag)
        return t

    def ready(self, t: Transfer) -> bool:
        """Non-blocking completion poll."""
        if t.done or t.cancelled:
            return t.done
        if not t._future.done():
            return False
        payload = t._future.result()
        return all(a.is_ready() if hasattr(a, "is_ready") else True
                   for a in payload.values())

    def fence(self, t: Transfer) -> dict:
        """Block until ``t`` has landed on device; returns its payload.

        The block time is accounted as ``stall_s``; submit→fence time
        that required no blocking is ``hidden_s`` (copy overlapped
        compute).  Raises :class:`TransferTimeout` after ``timeout_s``.
        """
        if t.cancelled:
            raise RuntimeError(f"fence on cancelled transfer {t.key!r}")
        if t.done:
            raise RuntimeError(f"double fence on transfer {t.key!r}")
        t0 = self.now()
        was_ready = self.ready(t)
        try:
            payload = t._future.result(timeout=self.timeout_s)
            jax.block_until_ready(payload)
        except (_FutureTimeout, TimeoutError):
            with self._lock:
                self.stats.timeouts += 1
            raise TransferTimeout(
                f"transfer {t.key!r} ({t.nbytes} bytes) did not complete "
                f"within {self.timeout_s}s") from None
        t1 = self.now()
        with self._lock:
            self.stats.fenced += 1
            if was_ready:
                self.stats.fences_ready += 1
            else:
                self.stats.fences_blocked += 1
            self.stats.stall_s += t1 - t0
            # pre-fence flight time: hidden behind whatever the caller
            # was doing between submit and fence
            self.stats.hidden_s += max(0.0, t0 - t.t_submit)
            self.stats.note_fence(t.tag, t1 - t0, max(0.0, t0 - t.t_submit))
        t.done = True
        t._payload = payload
        return payload

    def cancel(self, t: Transfer) -> None:
        """Drop an in-flight transfer: its payload will never be
        committed (the worker may still finish the copy; the buffers are
        simply garbage-collected)."""
        if t.done or t.cancelled:
            return
        t.cancelled = True
        t._future.cancel()
        with self._lock:
            self.stats.cancelled += 1
            self.stats.bytes_cancelled += t.nbytes
            self.stats.note_cancel(t.tag)

    def on_wave(self, seconds: Optional[float] = None) -> None:
        """Compute-progress hook: a wave was launched.  Wall time advances
        by itself for the real transport — this is a no-op here and a
        virtual-clock tick on :class:`FakeTransferEngine`."""

    def drain(self) -> None:
        """Testing/shutdown aid: wait for all queued copies."""
        self._pool.shutdown(wait=True)
        self._pool = ThreadPoolExecutor(max_workers=self._workers,
                                        thread_name_prefix="transfer-engine")

    def reset_stats(self) -> None:
        self.stats.reset()


class FakeTransferEngine:
    """Deterministic stall-injection transport with a virtual clock.

    Test control surface:

      * ``latency_s``      — default virtual copy duration per transfer;
      * ``schedule``       — ``{key: latency}`` per-key overrides; a
        ``None`` latency is a HUNG link (never completes; a fence raises
        :class:`TransferTimeout` instead of waiting forever);
      * ``wave_s``         — how much virtual time one compute wave is
        worth; ``on_wave()`` (called by ``PagedMoE`` after launching a
        wave) advances the clock by it, modelling copies flying while the
        wave computes;
      * ``advance(dt)``    — explicit clock tick;
      * ``complete(key)``  — force a specific in-flight transfer to be
        complete *now* (adversarial completion orderings).

    Payload values are materialized from the host arrays at fence time,
    so timing can never alter results — only the bookkeeping around them
    (which is the point of the harness).
    """

    def __init__(self, latency_s: float = 0.0,
                 schedule: Optional[dict] = None,
                 timeout_s: float = 30.0,
                 wave_s: float = 0.0):
        self.t = 0.0
        self.latency_s = float(latency_s)
        self.schedule = dict(schedule or {})
        self.timeout_s = float(timeout_s)
        self.wave_s = float(wave_s)
        self.stats = TransferStats()
        self._inflight: dict[Any, Transfer] = {}

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        """Tick the virtual clock: copies in flight make ``dt`` seconds
        of progress."""
        self.t += float(dt)

    def on_wave(self, seconds: Optional[float] = None) -> None:
        self.advance(self.wave_s if seconds is None else seconds)

    def complete(self, key: Any) -> None:
        """Force the in-flight transfer with ``key`` to complete now."""
        t = self._inflight.get(key)
        if t is None:
            raise KeyError(f"no in-flight transfer with key {key!r}")
        t.ready_at = self.t

    # ------------------------------------------------------------ stream

    def _latency(self, key: Any) -> Optional[float]:
        return self.schedule.get(key, self.latency_s)

    def submit(self, key: Any, arrays: dict, tag: str = "page") -> Transfer:
        t = Transfer(key, _nbytes(arrays), self.t, tag=tag)
        lat = self._latency(key)
        t.ready_at = math.inf if lat is None else self.t + float(lat)
        # hold HOST copies: a late mutation of the cache's host store must
        # not retroactively change what this transfer delivers
        t._payload = {n: np.array(a, copy=True) for n, a in arrays.items()}
        self._inflight[key] = t
        self.stats.submitted += 1
        self.stats.bytes_submitted += t.nbytes
        self.stats.note_submit(t.tag)
        return t

    def ready(self, t: Transfer) -> bool:
        return (not t.cancelled) and t.ready_at <= self.t

    def fence(self, t: Transfer) -> dict:
        if t.cancelled:
            raise RuntimeError(f"fence on cancelled transfer {t.key!r}")
        if t.done:
            raise RuntimeError(f"double fence on transfer {t.key!r}")
        if not self.ready(t):
            wait = t.ready_at - self.t
            if wait > self.timeout_s:
                self.stats.timeouts += 1
                raise TransferTimeout(
                    f"transfer {t.key!r} ({t.nbytes} bytes) hung: needs "
                    f"{'forever' if math.isinf(wait) else f'{wait:.3f}s'} "
                    f"> timeout {self.timeout_s}s of virtual time")
            self.stats.fences_blocked += 1
            self.stats.stall_s += wait
            # the flight time BEFORE the fence started overlapped whatever
            # the caller was doing (however the test advanced the clock)
            self.stats.hidden_s += max(0.0, self.t - t.t_submit)
            self.stats.note_fence(t.tag, wait, max(0.0, self.t - t.t_submit))
            self.t = t.ready_at
        else:
            self.stats.fences_ready += 1
            # copy finished before the fence: its whole duration was hidden
            self.stats.hidden_s += max(0.0, t.ready_at - t.t_submit)
            self.stats.note_fence(t.tag, 0.0,
                                  max(0.0, t.ready_at - t.t_submit))
        self.stats.fenced += 1
        t.done = True
        self._inflight.pop(t.key, None)
        payload = {n: jax.device_put(a) for n, a in t._payload.items()}
        t._payload = payload
        return payload

    def cancel(self, t: Transfer) -> None:
        if t.done or t.cancelled:
            return
        t.cancelled = True
        t._payload = None
        self._inflight.pop(t.key, None)
        self.stats.cancelled += 1
        self.stats.bytes_cancelled += t.nbytes
        self.stats.note_cancel(t.tag)

    def reset_stats(self) -> None:
        self.stats.reset()
