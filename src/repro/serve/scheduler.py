"""Task-aware serving scheduler: continuous batching over task buckets.

The multi-request generalization of the paper's zero-cost task switch
(§IV-F).  Requests carry a ``task_id``, an arrival time, and a prompt; the
scheduler keeps one *bucket* of decode slots per task (all slots in a bucket
share the task's gating network, so the jitted decode step is cached per
task exactly like the static engine), admits queued requests into freed
slots mid-flight, and rotates decode quanta round-robin across tasks so one
hot task cannot starve the rest.

Continuous batching mechanics:

  * each bucket owns a batched decode state (KV caches / recurrent state)
    of ``slots`` sequences plus a per-slot ``cache_pos`` vector — the
    vector-``cache_index`` decode path added to ``models/transformer.py``;
  * admission prefills the new request alone (batch 1, prompt padded up to
    a length bucket for attention archs so prefill compiles are bounded)
    and splices the resulting state into the freed slot with a donated
    per-leaf ``dynamic_update_slice`` (``_StateSlots``);
  * a request finishes on its own EOS/max-tokens; its slot is immediately
    reusable — no waiting for the rest of the batch (the static engine's
    tail waste, and where the throughput win comes from);
  * MoE archs: every decode step exports the per-expert dispatch counts
    (``forward(..., return_expert_counts=True)``) into a per-task
    ``ExpertUsage`` — the router statistics that drive expert-cache
    prefetch and make task-level sparsity observable.

``Scheduler`` is backend-generic: ``LMBackend`` serves autoregressive
decode; ``serve/vision.py`` provides a batched M³ViT backend so the paper's
own semseg/depth model is served through the same queue and fairness
machinery.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.dist.sharding import ShardingRules, use_rules
from repro.models import model as M
from repro.serve.engine import (ServeConfig, feedback_inputs, is_recurrent,
                                shard_state, state_batch_axes)
from repro.serve.expert_cache import ExpertUsage

__all__ = ["Request", "Scheduler", "LMBackend"]


@dataclass
class Request:
    rid: int
    task_id: int
    prompt: Any                     # (S0,) int32 tokens | (S0, d) embeddings
    max_new_tokens: int = 0         # LM: tokens to generate (>=1)
    arrival: float = 0.0
    eos_id: Optional[int] = None    # None => backend default
    # filled in by the scheduler
    tokens: list = field(default_factory=list)
    result: Any = None              # vision: prediction array
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def ttft(self) -> float:
        return (self.t_first or 0.0) - self.arrival

    @property
    def latency(self) -> float:
        return (self.t_done or 0.0) - self.arrival


def _pad_len(s0: int, bucket: int) -> int:
    return s0 if bucket <= 0 else -(-s0 // bucket) * bucket


class _StateSlots:
    """Recovers the per-leaf batch axis of a batched decode state, for
    splicing a batch-1 state into slot ``i`` (``LMBackend.admit_step``).

    The batch axis differs per leaf (stacked scanned layers prepend the
    period axis), so it is recovered structurally: build the state shape
    twice with different batch sizes and the axis whose dim changed is the
    batch axis.
    """

    def __init__(self, cfg: ArchConfig, max_len: int):
        self._axes = state_batch_axes(cfg, max_len)


class LMBackend:
    """Autoregressive decode backend with *mixed-task* batches: one decode
    step serves slots gated by different tasks (per-token gating — the
    per-slot generalization of the paper's zero-cost task switch), with
    vector cache positions and MoE router-usage export.  Admission prefills
    are per-task jitted (the §IV-F cached-pointer switch)."""

    bucketing = "mixed"   # one full-width bucket; fairness at admission

    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig,
                 rules: Optional[ShardingRules] = None,
                 prompt_pad: int = 16):
        if scfg.temperature > 0.0:
            raise ValueError("the scheduler decodes greedily (argmax is "
                             "fused into the jitted step)")
        from repro.serve.engine import _policy_override, place_params

        self.cfg = cfg = _policy_override(cfg, scfg)
        self.params = place_params(params, rules)
        self.scfg = scfg
        self.rules = rules
        self.recurrent = is_recurrent(cfg)
        # padded prefill relies on cache_len masking — attention archs only
        self.prompt_pad = 0 if self.recurrent else prompt_pad
        self.num_tasks = max(cfg.num_tasks,
                             cfg.moe.num_tasks if cfg.moe else 1)
        self.usage = (ExpertUsage(cfg.moe.num_experts, self.num_tasks)
                      if cfg.moe else None)
        self._slots_io = _StateSlots(cfg, scfg.max_len)
        self._prefill: dict[int, Any] = {}   # task -> jitted fused admit
        self._decode_fn = None               # one decode fn, tasks traced

    # ----------------------------------------------------------- steps

    def admit_step(self, task_id: int):
        """One fused jitted call per admission: batch-1 prefill against an
        in-graph zero state, greedy first token at the last REAL prompt
        position, and splice into the (donated) bucket state slot."""
        if task_id not in self._prefill:
            cfg, rules, scfg = self.cfg, self.rules, self.scfg
            axes = self._slots_io._axes

            def admit(params, inputs, big_state, slot, last_idx):
                with use_rules(rules):
                    small = M.init_state(cfg, 1, scfg.max_len)
                    logits, st, _ = M.forward(
                        params, inputs, cfg, state=small, cache_index=0,
                        task_id=task_id, return_state=True)
                tok = jnp.argmax(jax.lax.dynamic_index_in_dim(
                    logits, last_idx, axis=1, keepdims=False)[0], axis=-1)
                leaves, treedef = jax.tree_util.tree_flatten(big_state)
                small_leaves = jax.tree.leaves(st)
                out = [jax.lax.dynamic_update_slice_in_dim(b, s, slot,
                                                           axis=ax)
                       for b, s, ax in zip(leaves, small_leaves, axes)]
                return tok.astype(jnp.int32), \
                    jax.tree_util.tree_unflatten(treedef, out)

            self._prefill[task_id] = jax.jit(admit, donate_argnums=(2,))
        return self._prefill[task_id]

    def decode_step(self):
        """One decode fn for every batch composition: the per-slot task ids
        are a traced (B,) operand, so mixing tasks never recompiles."""
        if self._decode_fn is None:
            cfg, rules = self.cfg, self.rules
            want_counts = cfg.moe is not None

            def decode(params, toks, state, cache_pos, task_ids):
                with use_rules(rules):
                    out = M.forward(
                        params, feedback_inputs(cfg, toks), cfg, state=state,
                        cache_index=cache_pos, decode=True,
                        task_id=task_ids, return_state=True,
                        return_expert_counts=want_counts)
                if want_counts:
                    logits, st, _, counts = out
                else:
                    logits, st, _ = out
                    counts = jnp.zeros((0,), jnp.int32)
                # greedy sampling stays in-graph: one host sync per step
                return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), \
                    st, counts

            self._decode_fn = jax.jit(decode, donate_argnums=(2,))
        return self._decode_fn

    def make_bucket(self, task_id: int, slots: int) -> "LMTaskBucket":
        return LMTaskBucket(self, task_id, slots)


class LMTaskBucket:
    """``slots`` decode lanes.  With ``task_id=None`` (the LM backend's
    mixed mode) every slot carries its own task id into the decode step;
    with a fixed task id all lanes share one gating network."""

    def __init__(self, backend: LMBackend, task_id: Optional[int],
                 slots: int):
        self.backend = backend
        self.task_id = task_id
        self.slots = slots
        # decode lanes live batch-sharded over the data axes when a mesh is
        # active — admit splices and decode steps keep that placement
        self.state = shard_state(
            M.init_state(backend.cfg, slots, backend.scfg.max_len),
            backend.rules, backend._slots_io._axes)
        self.cache_pos = np.zeros((slots,), np.int32)
        self.last_tok = np.zeros((slots,), np.int32)
        self.task_slots = np.zeros((slots,), np.int32)
        self.reqs: list[Optional[Request]] = [None] * slots
        self.steps = 0               # decode steps executed
        self.slot_steps = 0          # decode slot-steps with a live request

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.reqs)

    @property
    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.reqs) if r is None]

    def _eos(self, req: Request) -> int:
        return self.backend.scfg.eos_id if req.eos_id is None else req.eos_id

    def _emit(self, req: Request, tok: int, now: float):
        """Record one generated token; returns True when the request is
        done (its own EOS or token budget — the slot frees immediately)."""
        req.tokens.append(tok)
        if req.t_first is None:
            req.t_first = now
        eos = self._eos(req)
        return (eos >= 0 and tok == eos) \
            or len(req.tokens) >= req.max_new_tokens

    def admit(self, req: Request, now: float) -> list[Request]:
        """Prefill ``req`` alone and splice it into a free slot."""
        b = self.backend
        slot = self.free_slots[0]
        prompt = np.asarray(req.prompt)[None]        # (1, S0[, d])
        s0 = prompt.shape[1]
        padded = _pad_len(s0, b.prompt_pad)
        if padded > b.scfg.max_len:
            raise ValueError(f"prompt {s0} > max_len {b.scfg.max_len}")
        if s0 + req.max_new_tokens - 1 > b.scfg.max_len:
            # decode step i writes K/V at position s0+i: reject a request
            # that cannot fit BEFORE it occupies a slot, not mid-flight
            raise ValueError(
                f"request {req.rid}: prompt {s0} + {req.max_new_tokens} "
                f"new tokens does not fit max_len {b.scfg.max_len}")
        if padded != s0:
            pad = np.zeros((1, padded - s0) + prompt.shape[2:], prompt.dtype)
            prompt = np.concatenate([prompt, pad], axis=1)
        tok, self.state = b.admit_step(req.task_id)(
            b.params, jnp.asarray(prompt), self.state, slot,
            jnp.int32(s0 - 1))
        tok = int(np.asarray(tok))
        req.t_admit = now
        self.cache_pos[slot] = s0
        self.last_tok[slot] = tok
        self.task_slots[slot] = req.task_id
        self.reqs[slot] = req
        if self._emit(req, tok, now):
            req.t_done = now
            self.reqs[slot] = None
            return [req]
        return []

    def run_quantum(self, n: int, now_fn,
                    admit_cb=None) -> list[Request]:
        """Up to ``n`` decode steps over the whole bucket; returns finished
        requests (their slots are already freed).  ``admit_cb`` runs before
        every step so slots freed mid-quantum refill immediately — the
        continuous part of continuous batching."""
        b = self.backend
        decode = b.decode_step()
        finished: list[Request] = []
        counts_sum = None
        for _ in range(n):
            if admit_cb is not None:
                admit_cb()
            if self.active == 0:
                break
            tok, self.state, counts = decode(
                b.params, jnp.asarray(self.last_tok), self.state,
                jnp.asarray(self.cache_pos), jnp.asarray(self.task_slots))
            self.steps += 1
            self.slot_steps += self.active
            if b.usage is not None:   # device-side accumulate, sync once
                counts_sum = counts if counts_sum is None \
                    else counts_sum + counts
            nxt = np.asarray(tok)
            now = now_fn()
            for i, req in enumerate(self.reqs):
                if req is None:
                    continue
                self.cache_pos[i] += 1
                self.last_tok[i] = nxt[i]
                if self._emit(req, int(nxt[i]), now):
                    # finished-first: a request whose generation exactly
                    # fills the cache frees its slot instead of tripping
                    # the overrun guard below
                    req.t_done = now
                    self.reqs[i] = None
                    self.cache_pos[i] = 0
                    self.last_tok[i] = 0
                    finished.append(req)
                elif self.cache_pos[i] >= b.scfg.max_len:
                    raise RuntimeError("decode ran past max_len")
        if counts_sum is not None and self.backend.usage is not None:
            c = np.asarray(counts_sum)
            if c.ndim == 2:        # mixed batch: one (E,) row per task
                for t in range(c.shape[0]):
                    if c[t].any():
                        self.backend.usage.update(c[t], t)
            else:
                self.backend.usage.update(c, self.task_id or 0)
        return finished


class Scheduler:
    """Task-fair continuous batching over a backend's buckets.

    Two bucketing modes (picked by ``backend.bucketing``):

      * ``"mixed"`` (LM decode): ONE bucket spanning ``total_slots`` decode
        lanes; freed slots are offered round-robin across task queues, so a
        hot task cannot monopolize admission while the decode batch itself
        mixes tasks (per-slot gating).
      * ``"per_task"`` (vision): one bucket per task, ``total_slots`` split
        evenly; decode/infer quanta rotate round-robin across runnable
        tasks.

    Either way total batch capacity equals a static engine's batch of
    ``total_slots``.
    """

    def __init__(self, backend, total_slots: int = 8, quantum: int = 4,
                 num_tasks: Optional[int] = None, clock=None):
        self.backend = backend
        self.num_tasks = num_tasks or getattr(backend, "num_tasks", 1)
        self.mixed = getattr(backend, "bucketing", "per_task") == "mixed"
        self.slots_per_bucket = total_slots if self.mixed \
            else max(1, total_slots // self.num_tasks)
        self.quantum = quantum
        self.clock = clock or time.perf_counter
        self.buckets: dict[Any, Any] = {}
        self.queues: dict[int, deque] = {}
        self.rotation: list[int] = []
        self._rr = 0
        self.finished: list[Request] = []
        self._t0: Optional[float] = None

    def now(self) -> float:
        if self._t0 is None:
            self._t0 = self.clock()
        return self.clock() - self._t0

    def submit(self, req: Request) -> None:
        if req.task_id not in self.queues:
            self.queues[req.task_id] = deque()
            self.rotation.append(req.task_id)
        self.queues[req.task_id].append(req)

    def _bucket(self, key):
        if key not in self.buckets:
            self.buckets[key] = self.backend.make_bucket(
                key, self.slots_per_bucket)
        return self.buckets[key]

    def _runnable(self, task_id: int, now: float) -> bool:
        q = self.queues.get(task_id)
        queued = bool(q) and q[0].arrival <= now
        bucket = self.buckets.get(task_id)
        return queued or (bucket is not None and bucket.active > 0)

    def _peek_next_task(self, current: int, now: float) -> Optional[int]:
        """The task the rotation will pick after ``current`` — the cross-
        bucket lookahead target whose hot experts can stream behind the
        quantum that is about to run."""
        for off in range(len(self.rotation)):
            t = self.rotation[(self._rr + off) % len(self.rotation)]
            if t != current and self._runnable(t, now):
                return t
        return None

    def pending(self) -> bool:
        if any(self.queues.get(t) for t in self.rotation):
            return True
        return any(b.active > 0 for b in self.buckets.values())

    def _admit_mixed(self, bucket) -> bool:
        """Offer freed slots round-robin across task queues (one request per
        runnable task per lap) — admission-level fairness for mixed mode."""
        admitted = False
        progress = True
        while bucket.free_slots and progress and self.rotation:
            progress = False
            for off in range(len(self.rotation)):
                if not bucket.free_slots:
                    break
                t = self.rotation[(self._rr + off) % len(self.rotation)]
                q = self.queues.get(t)
                if q and q[0].arrival <= self.now():
                    self.finished.extend(
                        bucket.admit(q.popleft(), self.now()))
                    self._rr = (self._rr + off + 1) % len(self.rotation)
                    admitted = progress = True
                    break
        return admitted

    def step(self) -> bool:
        """One scheduling quantum.  Returns False when nothing was runnable
        (e.g. every remaining arrival is in the future)."""
        now = self.now()
        if self.mixed:
            bucket = self._bucket(None)
            admitted = self._admit_mixed(bucket)
            if bucket.active == 0 and not admitted:
                return False
            self.finished.extend(bucket.run_quantum(
                self.quantum, self.now,
                admit_cb=lambda: self._admit_mixed(bucket)))
            return True
        for off in range(len(self.rotation)):
            task = self.rotation[(self._rr + off) % len(self.rotation)]
            if self._runnable(task, now):
                self._rr = (self._rr + off + 1) % len(self.rotation)
                bucket = self._bucket(task)
                q = self.queues[task]

                def admit():
                    while bucket.free_slots and q \
                            and q[0].arrival <= self.now():
                        done = bucket.admit(q.popleft(), self.now())
                        self.finished.extend(done)

                admit()
                # router lookahead across buckets: submit the NEXT task's
                # usage-hot experts before this quantum launches, so their
                # copies ride behind its compute.  The current task's own
                # prefetch runs inside run_quantum AFTER this, so where the
                # two sets conflict the current task wins the slots.
                la = getattr(self.backend, "lookahead", None)
                if la is not None:
                    nxt = self._peek_next_task(task, now)
                    if nxt is not None:
                        la(nxt)
                self.finished.extend(bucket.run_quantum(
                    self.quantum, self.now, admit_cb=admit))
                return True
        return False

    def run(self, requests=None) -> list[Request]:
        """Submit ``requests`` (optional) and drain everything.  Spins (with
        a tiny sleep) while all remaining arrivals are in the future —
        open-loop driving."""
        for r in requests or ():
            self.submit(r)
        self.now()                     # start the clock
        while self.pending():
            if not self.step():
                time.sleep(0.0005)
        return self.finished

    # ------------------------------------------------------------ metrics

    def metrics(self) -> dict[str, Any]:
        done = [r for r in self.finished if r.t_done is not None]
        toks = sum(len(r.tokens) for r in done)
        items = len(done)
        span = max((r.t_done for r in done), default=0.0) - \
            min((r.arrival for r in done), default=0.0)
        lat = np.array([r.latency for r in done]) if done else np.zeros(1)
        ttft = np.array([r.ttft for r in done if r.t_first is not None])
        out: dict[str, Any] = {
            "requests": items,
            "tokens": toks,
            "span_s": span,
            "tok_per_s": toks / span if span > 0 else 0.0,
            "items_per_s": items / span if span > 0 else 0.0,
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p99_s": float(np.percentile(lat, 99)),
            "ttft_p50_s": float(np.percentile(ttft, 50)) if ttft.size else 0.0,
            "per_task": {
                t: sum(1 for r in done if r.task_id == t)
                for t in self.rotation
            },
        }
        usage = getattr(self.backend, "usage", None)
        if usage is not None:
            out["expert_usage_task_overlap"] = usage.task_overlap()
        slot_steps = sum(getattr(b, "slot_steps", 0)
                         for b in self.buckets.values())
        steps = sum(getattr(b, "steps", 0) for b in self.buckets.values())
        cap = self.slots_per_bucket
        if steps:
            out["slot_utilization"] = slot_steps / (steps * cap)
        cache_stats = getattr(self.backend, "cache_stats", None)
        if callable(cache_stats):
            out["expert_cache"] = cache_stats()
        return out
