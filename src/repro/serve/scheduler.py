"""Task-aware serving scheduler: continuous batching over task buckets.

The multi-request generalization of the paper's zero-cost task switch
(§IV-F).  Requests carry a ``task_id``, an arrival time, and a prompt; the
scheduler keeps one *bucket* of decode slots per task (all slots in a bucket
share the task's gating network, so the jitted decode step is cached per
task exactly like the static engine), admits queued requests into freed
slots mid-flight, and rotates decode quanta round-robin across tasks so one
hot task cannot starve the rest.

Continuous batching mechanics:

  * each bucket owns a batched decode state (KV caches / recurrent state)
    of ``slots`` sequences plus a per-slot ``cache_pos`` vector — the
    vector-``cache_index`` decode path added to ``models/transformer.py``;
  * admission prefills the new request alone (batch 1, prompt padded up to
    a length bucket for attention archs so prefill compiles are bounded)
    and splices the resulting state into the freed slot with a donated
    per-leaf ``dynamic_update_slice`` (``_StateSlots``);
  * a request finishes on its own EOS/max-tokens; its slot is immediately
    reusable — no waiting for the rest of the batch (the static engine's
    tail waste, and where the throughput win comes from);
  * MoE archs: every decode step exports the per-expert dispatch counts
    (``forward(..., return_expert_counts=True)``) into a per-task
    ``ExpertUsage`` — the router statistics that drive expert-cache
    prefetch and make task-level sparsity observable.

SLO-aware serving (``Scheduler(..., slo=SLOPolicy(...))``, the
``repro.serve.slo`` subsystem):

  * requests carry a *tier* (interactive vs batch) with TTFT/TPOT
    deadlines; admission laps serve interactive queues first;
  * a due interactive request with no free slot *preempts* a batch-tier
    decode slot: its KV/recurrent state is parked bit-exactly (int8 KV
    caches make parked bytes ~4× cheaper — ``slo/preempt.py``) and later
    spliced back through the same fused admit-splice, continuing decode
    token-identically;
  * a radix prefix cache (``ServeConfig.prefix_cache`` > 0) seeds
    admissions from cached shared-prompt prefill state, skipping the
    matched tokens;
  * long prompts admit in ``prefill_chunk``-token chunks interleaved
    with decode steps (one chunk per step), so a long prefill no longer
    head-of-line-blocks every decode slot;
  * ``metrics()`` reports per-tier TTFT/TPOT percentiles, preemption
    counts, and goodput-under-SLO alongside tok/s.

``Scheduler`` is backend-generic: ``LMBackend`` serves autoregressive
decode; ``serve/vision.py`` provides a batched M³ViT backend so the paper's
own semseg/depth model is served through the same queue and fairness
machinery (vision "preemption" is a staged-batch bump — inference is
stateless, so it is trivially result-identical).
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.dist.sharding import ShardingRules, use_rules
from repro.models import model as M
from repro.serve.engine import (ServeConfig, feedback_inputs, is_recurrent,
                                shard_state, state_batch_axes)
from repro.serve.expert_cache import ExpertUsage
from repro.serve.slo.preempt import SlotParker
from repro.serve.slo.prefix import RadixPrefixCache
from repro.serve.slo.tiers import (SLOPolicy, goodput, is_preemptible,
                                   meets_slo, request_tpot)

__all__ = ["Request", "Scheduler", "LMBackend"]


@dataclass
class Request:
    rid: int
    task_id: int
    prompt: Any                     # (S0,) int32 tokens | (S0, d) embeddings
    max_new_tokens: int = 0         # LM: tokens to generate (>=1)
    arrival: float = 0.0
    eos_id: Optional[int] = None    # None => backend default
    # SLO tier (see repro.serve.slo.tiers): deadlines are None until the
    # trace/tier tags them; ``tier`` names the service class
    tier: str = "interactive"
    tenant: int = 0
    ttft_slo_s: Optional[float] = None
    tpot_slo_s: Optional[float] = None
    # filled in by the scheduler
    tokens: list = field(default_factory=list)
    result: Any = None              # vision: prediction array
    t_admit: Optional[float] = None
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    preemptions: int = 0            # times this request's slot was parked
    prefix_hit_tokens: int = 0      # prefill tokens skipped via prefix cache

    @property
    def ttft(self) -> float:
        """Arrival -> first token; nan until the first token exists (a
        ``0 - arrival`` garbage value here used to poison percentiles)."""
        if self.t_first is None:
            return float("nan")
        return self.t_first - self.arrival

    @property
    def latency(self) -> float:
        if self.t_done is None:
            return float("nan")
        return self.t_done - self.arrival

    @property
    def tpot(self) -> float:
        """Mean time per output token after the first (nan unfinished)."""
        return request_tpot(self)


def _pad_len(s0: int, bucket: int) -> int:
    return s0 if bucket <= 0 else -(-s0 // bucket) * bucket


def _state_bytes(state) -> int:
    return sum(int(l.nbytes) for l in jax.tree.leaves(state))


class _StateSlots:
    """Recovers the per-leaf batch axis of a batched decode state, for
    splicing a batch-1 state into slot ``i`` (``LMBackend.admit_step``).

    The batch axis differs per leaf (stacked scanned layers prepend the
    period axis), so it is recovered structurally: build the state shape
    twice with different batch sizes and the axis whose dim changed is the
    batch axis.
    """

    def __init__(self, cfg: ArchConfig, max_len: int):
        self._axes = state_batch_axes(cfg, max_len)


@dataclass
class _PrefillJob:
    """An in-flight chunked admission: a reserved slot plus a batch-1
    staging state advanced one ``prefill_chunk`` per decode step."""

    req: Request
    slot: int
    small: Any          # batch-1 staging state
    prompt: np.ndarray  # (1, S0[, d])
    off: int            # next prefill position (prefix-matched tokens skip)
    s0: int


class LMBackend:
    """Autoregressive decode backend with *mixed-task* batches: one decode
    step serves slots gated by different tasks (per-token gating — the
    per-slot generalization of the paper's zero-cost task switch), with
    vector cache positions and MoE router-usage export.  Admission prefills
    are per-task jitted (the §IV-F cached-pointer switch)."""

    bucketing = "mixed"   # one full-width bucket; fairness at admission

    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig,
                 rules: Optional[ShardingRules] = None,
                 prompt_pad: int = 16):
        if scfg.temperature > 0.0:
            raise ValueError("the scheduler decodes greedily (argmax is "
                             "fused into the jitted step)")
        from repro.serve.engine import _policy_override, place_params

        self.cfg = cfg = _policy_override(cfg, scfg)
        self.params = place_params(params, rules)
        self.scfg = scfg
        self.rules = rules
        self.recurrent = is_recurrent(cfg)
        # padded prefill relies on cache_len masking — attention archs only
        self.prompt_pad = 0 if self.recurrent else prompt_pad
        self.num_tasks = max(cfg.num_tasks,
                             cfg.moe.num_tasks if cfg.moe else 1)
        self.usage = (ExpertUsage(cfg.moe.num_experts, self.num_tasks)
                      if cfg.moe else None)
        self._slots_io = _StateSlots(cfg, scfg.max_len)
        self._prefill: dict[int, Any] = {}   # task -> jitted fused admit
        self._decode_fn = None               # one decode fn, tasks traced
        self._staged: dict[int, tuple] = {}  # task -> (mid, finish) jits
        self._parkers: dict[str, SlotParker] = {}
        # shared prompt-prefix reuse needs the attention truncation
        # property (stale rows masked by causal/cache_len); recurrent
        # state is a running reduction, so no cache for those archs
        self.prefix: Optional[RadixPrefixCache] = None
        if scfg.prefix_cache > 0 and not self.recurrent:
            self.prefix = RadixPrefixCache(
                scfg.prefix_cache, min_match=max(1, scfg.prefix_min))

    # ----------------------------------------------------------- steps

    def admit_step(self, task_id: int):
        """One fused jitted call per admission: batch-1 prefill against an
        in-graph zero state, greedy first token at the last REAL prompt
        position, and splice into the (donated) bucket state slot."""
        if task_id not in self._prefill:
            cfg, rules, scfg = self.cfg, self.rules, self.scfg
            axes = self._slots_io._axes

            def admit(params, inputs, big_state, slot, last_idx):
                with use_rules(rules):
                    small = M.init_state(cfg, 1, scfg.max_len)
                    logits, st, _ = M.forward(
                        params, inputs, cfg, state=small, cache_index=0,
                        task_id=task_id, return_state=True)
                tok = jnp.argmax(jax.lax.dynamic_index_in_dim(
                    logits, last_idx, axis=1, keepdims=False)[0], axis=-1)
                leaves, treedef = jax.tree_util.tree_flatten(big_state)
                small_leaves = jax.tree.leaves(st)
                out = [jax.lax.dynamic_update_slice_in_dim(b, s, slot,
                                                           axis=ax)
                       for b, s, ax in zip(leaves, small_leaves, axes)]
                return tok.astype(jnp.int32), \
                    jax.tree_util.tree_unflatten(treedef, out)

            self._prefill[task_id] = jax.jit(admit, donate_argnums=(2,))
        return self._prefill[task_id]

    def staged_steps(self, task_id: int):
        """Jitted staged-admission steps, cached per task.

        ``mid(params, toks, small, idx) -> small``           one chunk;
        ``finish(params, toks, small, idx, last_rel, big, slot)
              -> (first_tok, small_out, big_out)``  final chunk + splice.

        Unlike the fused ``admit_step`` these run against an *explicit*
        batch-1 staging state, which is what lets an admission (a) start
        from a prefix-cache entry at offset ``idx`` and (b) advance one
        chunk at a time between decode steps.  ``small`` is never donated
        — a prefix-cache entry must survive being read — and
        ``small_out`` is returned so the finished prompt can be inserted
        into the cache.
        """
        if task_id not in self._staged:
            cfg, rules = self.cfg, self.rules
            axes = self._slots_io._axes

            def mid(params, toks, small, idx):
                with use_rules(rules):
                    _, st, _ = M.forward(
                        params, toks, cfg, state=small, cache_index=idx,
                        task_id=task_id, return_state=True,
                        logits_mode="last")
                return st

            def finish(params, toks, small, idx, last_rel, big, slot):
                with use_rules(rules):
                    logits, st, _ = M.forward(
                        params, toks, cfg, state=small, cache_index=idx,
                        task_id=task_id, return_state=True)
                tok = jnp.argmax(jax.lax.dynamic_index_in_dim(
                    logits, last_rel, axis=1, keepdims=False)[0], axis=-1)
                leaves, treedef = jax.tree_util.tree_flatten(big)
                small_leaves = jax.tree.leaves(st)
                out = [jax.lax.dynamic_update_slice_in_dim(b, s, slot,
                                                           axis=ax)
                       for b, s, ax in zip(leaves, small_leaves, axes)]
                return (tok.astype(jnp.int32), st,
                        jax.tree_util.tree_unflatten(treedef, out))

            self._staged[task_id] = (
                jax.jit(mid), jax.jit(finish, donate_argnums=(5,)))
        return self._staged[task_id]

    def decode_step(self):
        """One decode fn for every batch composition: the per-slot task ids
        are a traced (B,) operand, so mixing tasks never recompiles."""
        if self._decode_fn is None:
            cfg, rules = self.cfg, self.rules
            want_counts = cfg.moe is not None

            def decode(params, toks, state, cache_pos, task_ids):
                with use_rules(rules):
                    out = M.forward(
                        params, feedback_inputs(cfg, toks), cfg, state=state,
                        cache_index=cache_pos, decode=True,
                        task_id=task_ids, return_state=True,
                        return_expert_counts=want_counts)
                if want_counts:
                    logits, st, _, counts = out
                else:
                    logits, st, _ = out
                    counts = jnp.zeros((0,), jnp.int32)
                # greedy sampling stays in-graph: one host sync per step
                return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), \
                    st, counts

            self._decode_fn = jax.jit(decode, donate_argnums=(2,))
        return self._decode_fn

    def parker(self, compress: str = "none") -> SlotParker:
        """Park/restore machinery for this backend's state layout (one
        jit pair per compression mode, shared by every bucket)."""
        if compress not in self._parkers:
            shapes = jax.tree.leaves(jax.eval_shape(
                lambda: M.init_state(self.cfg, 1, self.scfg.max_len)))
            self._parkers[compress] = SlotParker(
                self._slots_io._axes, shapes, compress)
        return self._parkers[compress]

    def make_bucket(self, task_id: int, slots: int) -> "LMTaskBucket":
        return LMTaskBucket(self, task_id, slots)


class LMTaskBucket:
    """``slots`` decode lanes.  With ``task_id=None`` (the LM backend's
    mixed mode) every slot carries its own task id into the decode step;
    with a fixed task id all lanes share one gating network."""

    def __init__(self, backend: LMBackend, task_id: Optional[int],
                 slots: int):
        self.backend = backend
        self.task_id = task_id
        self.slots = slots
        # decode lanes live batch-sharded over the data axes when a mesh is
        # active — admit splices and decode steps keep that placement
        self.state = shard_state(
            M.init_state(backend.cfg, slots, backend.scfg.max_len),
            backend.rules, backend._slots_io._axes)
        self.cache_pos = np.zeros((slots,), np.int32)
        self.last_tok = np.zeros((slots,), np.int32)
        self.task_slots = np.zeros((slots,), np.int32)
        self.reqs: list[Optional[Request]] = [None] * slots
        self.jobs: list[_PrefillJob] = []   # in-flight chunked admissions
        self.reserved: set[int] = set()     # slots held by jobs
        self.steps = 0               # decode steps executed
        self.slot_steps = 0          # decode slot-steps with a live request
        self.prefill_chunks = 0      # interleaved chunk steps executed

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.reqs)

    @property
    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.reqs)
                if r is None and i not in self.reserved]

    def _eos(self, req: Request) -> int:
        return self.backend.scfg.eos_id if req.eos_id is None else req.eos_id

    def _emit(self, req: Request, tok: int, now: float):
        """Record one generated token; returns True when the request is
        done (its own EOS or token budget — the slot frees immediately)."""
        req.tokens.append(tok)
        if req.t_first is None:
            req.t_first = now
        eos = self._eos(req)
        return (eos >= 0 and tok == eos) \
            or len(req.tokens) >= req.max_new_tokens

    # ------------------------------------------------------- admission

    def _activate(self, req: Request, slot: int, tok: int, s0: int,
                  now: float) -> list[Request]:
        """Common admission tail: wire the slot and emit the first token."""
        self.cache_pos[slot] = s0
        self.last_tok[slot] = tok
        self.task_slots[slot] = req.task_id
        self.reqs[slot] = req
        if self._emit(req, tok, now):
            req.t_done = now
            self.reqs[slot] = None
            self.cache_pos[slot] = 0
            self.last_tok[slot] = 0
            return [req]
        return []

    def admit(self, req: Request, now: float,
              chunk_interleave: bool = False) -> list[Request]:
        """Prefill ``req`` and splice it into a free slot.

        Three admission paths, cheapest applicable wins:
          * fused one-shot (no prefix cache): batch-1 prefill against an
            in-graph zero state, one jitted call;
          * staged one-shot: explicit staging state — seeded from the
            radix prefix cache when the prompt shares a cached prefix
            (only the suffix is prefilled, at ``cache_index = L``) and
            inserted back into the cache afterwards;
          * chunked job (``chunk_interleave``): the slot is reserved and
            the prompt advances one ``prefill_chunk`` per decode step
            (``advance_prefill``), so long prompts stop head-of-line-
            blocking the decode batch.
        """
        b = self.backend
        slot = self.free_slots[0]
        prompt = np.asarray(req.prompt)[None]        # (1, S0[, d])
        s0 = prompt.shape[1]
        padded = _pad_len(s0, b.prompt_pad)
        if padded > b.scfg.max_len:
            raise ValueError(f"prompt {s0} > max_len {b.scfg.max_len}")
        if s0 + req.max_new_tokens - 1 > b.scfg.max_len:
            # decode step i writes K/V at position s0+i: reject a request
            # that cannot fit BEFORE it occupies a slot, not mid-flight
            raise ValueError(
                f"request {req.rid}: prompt {s0} + {req.max_new_tokens} "
                f"new tokens does not fit max_len {b.scfg.max_len}")

        # shared-prefix lookup (token prompts on attention archs only)
        entry, matched = None, 0
        if b.prefix is not None and prompt.ndim == 2:
            entry, matched = b.prefix.lookup(prompt[0])
            matched = min(matched, s0 - 1)   # always prefill >= 1 token
            if entry is None or matched < b.prefix.min_match:
                entry, matched = None, 0

        chunk = b.scfg.prefill_chunk
        suffix_len = s0 - matched
        # chunking only pays while there are active decoders to protect:
        # on an idle batch a one-shot prefill blocks nobody and is far
        # cheaper than a chunk-per-step dispatch train
        if (chunk_interleave and self.active > 0 and chunk > 0
                and suffix_len > chunk and not b.recurrent
                and matched + _pad_len(suffix_len, chunk) <= b.scfg.max_len):
            small = entry if entry is not None \
                else M.init_state(b.cfg, 1, b.scfg.max_len)
            self.jobs.append(_PrefillJob(req=req, slot=slot, small=small,
                                         prompt=prompt, off=matched, s0=s0))
            self.reserved.add(slot)
            req.t_admit = now
            req.prefix_hit_tokens = matched
            return []

        req.t_admit = now
        if b.prefix is None or prompt.ndim != 2:
            # legacy fused path (also serves embedding prompts)
            if padded != s0:
                pad = np.zeros((1, padded - s0) + prompt.shape[2:],
                               prompt.dtype)
                prompt = np.concatenate([prompt, pad], axis=1)
            tok, self.state = b.admit_step(req.task_id)(
                b.params, jnp.asarray(prompt), self.state, slot,
                jnp.int32(s0 - 1))
            return self._activate(req, slot, int(np.asarray(tok)), s0, now)
        tok = self._admit_staged(req, slot, entry, matched, prompt)
        return self._activate(req, slot, tok, s0, now)

    def _admit_staged(self, req: Request, slot: int, entry, matched: int,
                      prompt: np.ndarray) -> int:
        """One-shot staged admission: suffix prefill at offset ``matched``
        (0 with a fresh staging state on a prefix miss), splice, and
        insert the finished prompt's state into the prefix cache."""
        b = self.backend
        s0 = prompt.shape[1]
        if matched and matched + _pad_len(s0 - matched, b.prompt_pad) \
                > b.scfg.max_len:
            # padded suffix would write past the cache: drop the hit
            # rather than let dynamic_update_slice clamp-shift the rows
            entry, matched = None, 0
        small = entry if entry is not None \
            else M.init_state(b.cfg, 1, b.scfg.max_len)
        suffix = prompt[:, matched:]
        padded = _pad_len(suffix.shape[1], b.prompt_pad)
        if padded != suffix.shape[1]:
            pad = np.zeros((1, padded - suffix.shape[1]) + suffix.shape[2:],
                           suffix.dtype)
            suffix = np.concatenate([suffix, pad], axis=1)
        _, finish = b.staged_steps(req.task_id)
        tok, small_out, self.state = finish(
            b.params, jnp.asarray(suffix), small, jnp.int32(matched),
            jnp.int32(s0 - matched - 1), self.state, slot)
        req.prefix_hit_tokens = matched
        b.prefix.insert(prompt[0], small_out, _state_bytes(small_out))
        return int(np.asarray(tok))

    def advance_prefill(self, now_fn) -> list[Request]:
        """Advance EVERY chunked admission by one chunk (called once per
        decode step, the interleaving grain).  Jobs progress in parallel —
        a reserved slot idles for ~(prompt/chunk) steps, not for the sum
        of every queued prompt's chunks.  A job's final chunk fuses
        first-token sampling with the slot splice, exactly like a one-shot
        admission — token-identical either way."""
        b = self.backend
        finished: list[Request] = []
        chunk = b.scfg.prefill_chunk
        for job in list(self.jobs):
            mid, finish = b.staged_steps(job.req.task_id)
            remaining = job.s0 - job.off
            self.prefill_chunks += 1
            if remaining > chunk:
                toks = jnp.asarray(job.prompt[:, job.off:job.off + chunk])
                job.small = mid(b.params, toks, job.small,
                                jnp.int32(job.off))
                job.off += chunk
                continue
            tail = job.prompt[:, job.off:]
            if remaining < chunk:   # pad final chunk to the compiled width
                pad = np.zeros((1, chunk - remaining) + tail.shape[2:],
                               tail.dtype)
                tail = np.concatenate([tail, pad], axis=1)
            tok, small_out, self.state = finish(
                b.params, jnp.asarray(tail), job.small, jnp.int32(job.off),
                jnp.int32(remaining - 1), self.state, job.slot)
            if b.prefix is not None and job.prompt.ndim == 2:
                b.prefix.insert(job.prompt[0], small_out,
                                _state_bytes(small_out))
            self.jobs.remove(job)
            self.reserved.discard(job.slot)
            finished.extend(self._activate(
                job.req, job.slot, int(np.asarray(tok)), job.s0, now_fn()))
        return finished

    # ------------------------------------------------------ preemption

    def pick_victim(self) -> Optional[int]:
        """The preemption victim: the *youngest* preemptible (batch-tier)
        slot — the least sunk decode work in the current burst."""
        cands = [(r.t_admit or 0.0, i) for i, r in enumerate(self.reqs)
                 if r is not None and is_preemptible(r)]
        return max(cands)[1] if cands else None

    def park(self, slot: int, parker: SlotParker) -> dict:
        """Evict ``slot``: extract its state bit-exactly (optionally int8-
        packed) and free the lane.  Returns the parked record."""
        req = self.reqs[slot]
        parked = {"req": req,
                  "state": parker.park(self.state, slot),
                  "cache_pos": int(self.cache_pos[slot]),
                  "last_tok": int(self.last_tok[slot])}
        req.preemptions += 1
        self.reqs[slot] = None
        self.cache_pos[slot] = 0
        self.last_tok[slot] = 0
        return parked

    def restore(self, parked: dict, parker: SlotParker) -> int:
        """Splice a parked record back into a free slot and resume decode
        where it left off (same cache position, same feedback token)."""
        slot = self.free_slots[0]
        self.state = parker.restore(self.state, parked["state"], slot)
        req = parked["req"]
        self.cache_pos[slot] = parked["cache_pos"]
        self.last_tok[slot] = parked["last_tok"]
        self.task_slots[slot] = req.task_id
        self.reqs[slot] = req
        return slot

    # ---------------------------------------------------------- decode

    def run_quantum(self, n: int, now_fn,
                    admit_cb=None) -> list[Request]:
        """Up to ``n`` decode steps over the whole bucket; returns finished
        requests (their slots are already freed).  ``admit_cb`` runs before
        every step so slots freed mid-quantum refill immediately — the
        continuous part of continuous batching.  In-flight chunked
        admissions advance one chunk per step, interleaved with decode."""
        b = self.backend
        decode = b.decode_step()
        finished: list[Request] = []
        counts_sum = None
        for _ in range(n):
            if admit_cb is not None:
                admit_cb()
            if self.jobs:
                finished.extend(self.advance_prefill(now_fn))
                # no decodable slot -> no decode latency to protect:
                # drain prefill chunks at full speed until a job
                # activates (admissions stay live via admit_cb)
                while self.active == 0 and self.jobs:
                    if admit_cb is not None:
                        admit_cb()
                    finished.extend(self.advance_prefill(now_fn))
            if self.active == 0:
                break
            tok, self.state, counts = decode(
                b.params, jnp.asarray(self.last_tok), self.state,
                jnp.asarray(self.cache_pos), jnp.asarray(self.task_slots))
            self.steps += 1
            self.slot_steps += self.active
            if b.usage is not None:   # device-side accumulate, sync once
                counts_sum = counts if counts_sum is None \
                    else counts_sum + counts
            nxt = np.asarray(tok)
            now = now_fn()
            for i, req in enumerate(self.reqs):
                if req is None:
                    continue
                self.cache_pos[i] += 1
                self.last_tok[i] = nxt[i]
                if self._emit(req, int(nxt[i]), now):
                    # finished-first: a request whose generation exactly
                    # fills the cache frees its slot instead of tripping
                    # the overrun guard below
                    req.t_done = now
                    self.reqs[i] = None
                    self.cache_pos[i] = 0
                    self.last_tok[i] = 0
                    finished.append(req)
                elif self.cache_pos[i] >= b.scfg.max_len:
                    raise RuntimeError("decode ran past max_len")
        if counts_sum is not None and self.backend.usage is not None:
            c = np.asarray(counts_sum)
            if c.ndim == 2:        # mixed batch: one (E,) row per task
                for t in range(c.shape[0]):
                    if c[t].any():
                        self.backend.usage.update(c[t], t)
            else:
                self.backend.usage.update(c, self.task_id or 0)
        return finished


def _interactive(req: Request) -> bool:
    return not is_preemptible(req)


class Scheduler:
    """Task-fair continuous batching over a backend's buckets.

    Two bucketing modes (picked by ``backend.bucketing``):

      * ``"mixed"`` (LM decode): ONE bucket spanning ``total_slots`` decode
        lanes; freed slots are offered round-robin across task queues, so a
        hot task cannot monopolize admission while the decode batch itself
        mixes tasks (per-slot gating).
      * ``"per_task"`` (vision): one bucket per task, ``total_slots`` split
        evenly; decode/infer quanta rotate round-robin across runnable
        tasks.

    Either way total batch capacity equals a static engine's batch of
    ``total_slots``.

    ``slo`` (an :class:`repro.serve.slo.SLOPolicy`) turns on tiered
    admission: interactive queues admit first (still round-robin across
    tasks within a tier), due interactive requests preempt batch-tier
    decode slots (KV park/restore — bit-exact), parked requests restore
    FIFO once the burst passes, and long prompts admit chunk-interleaved.
    """

    def __init__(self, backend, total_slots: int = 8, quantum: int = 4,
                 num_tasks: Optional[int] = None, clock=None,
                 slo: Optional[SLOPolicy] = None):
        self.backend = backend
        self.num_tasks = num_tasks or getattr(backend, "num_tasks", 1)
        self.mixed = getattr(backend, "bucketing", "per_task") == "mixed"
        self.slots_per_bucket = total_slots if self.mixed \
            else max(1, total_slots // self.num_tasks)
        self.quantum = quantum
        self.clock = clock or time.perf_counter
        self.slo = slo
        self.buckets: dict[Any, Any] = {}
        self.queues: dict[int, deque] = {}
        self.rotation: list[int] = []
        self._rr = 0
        self.finished: list[Request] = []
        self._t0: Optional[float] = None
        # SLO machinery
        self.parked: deque = deque()
        self.preemptions = 0
        self.restores = 0
        self.parked_bytes = 0
        self.parked_bytes_peak = 0
        self._parker: Optional[SlotParker] = None

    def now(self) -> float:
        if self._t0 is None:
            self._t0 = self.clock()
        return self.clock() - self._t0

    def submit(self, req: Request) -> None:
        if req.task_id not in self.queues:
            self.queues[req.task_id] = deque()
            self.rotation.append(req.task_id)
        self.queues[req.task_id].append(req)

    def _bucket(self, key):
        if key not in self.buckets:
            self.buckets[key] = self.backend.make_bucket(
                key, self.slots_per_bucket)
        return self.buckets[key]

    def _runnable(self, task_id: int, now: float) -> bool:
        q = self.queues.get(task_id)
        queued = bool(q) and (q[0].arrival <= now if self.slo is None
                              else any(r.arrival <= now for r in q))
        bucket = self.buckets.get(task_id)
        return queued or (bucket is not None and bucket.active > 0)

    def _peek_next_task(self, current: int, now: float) -> Optional[int]:
        """The task the rotation will pick after ``current`` — the cross-
        bucket lookahead target whose hot experts can stream behind the
        quantum that is about to run."""
        for off in range(len(self.rotation)):
            t = self.rotation[(self._rr + off) % len(self.rotation)]
            if t != current and self._runnable(t, now):
                return t
        return None

    def pending(self) -> bool:
        if self.parked:
            return True
        if any(self.queues.get(t) for t in self.rotation):
            return True
        return any(b.active > 0 or getattr(b, "jobs", None)
                   for b in self.buckets.values())

    # ------------------------------------------------------- admission

    def _pop_due(self, task: int, now: float, pred=None):
        """Pop the first due request in ``task``'s queue matching ``pred``
        (SLO mode scans past not-yet-due heads; legacy admission is
        strictly head-of-queue and does not use this)."""
        q = self.queues.get(task)
        if not q:
            return None
        for i, r in enumerate(q):
            if r.arrival <= now and (pred is None or pred(r)):
                del q[i]
                return r
        return None

    def _due_any(self, now: float, pred) -> bool:
        return any(r.arrival <= now and pred(r)
                   for q in self.queues.values() for r in q)

    def _task_due(self, task: int, now: float, pred) -> bool:
        return any(r.arrival <= now and pred(r)
                   for r in self.queues.get(task, ()))

    def _admit_mixed(self, bucket) -> bool:
        """Offer freed slots round-robin across task queues (one request per
        runnable task per lap) — admission-level fairness for mixed mode."""
        admitted = False
        progress = True
        while bucket.free_slots and progress and self.rotation:
            progress = False
            for off in range(len(self.rotation)):
                if not bucket.free_slots:
                    break
                t = self.rotation[(self._rr + off) % len(self.rotation)]
                q = self.queues.get(t)
                if q and q[0].arrival <= self.now():
                    self.finished.extend(
                        bucket.admit(q.popleft(), self.now()))
                    self._rr = (self._rr + off + 1) % len(self.rotation)
                    admitted = progress = True
                    break
        return admitted

    def _admit_lap(self, bucket, pred, limit: Optional[int] = None) -> bool:
        """Round-robin admission laps restricted to ``pred`` requests —
        the SLO-mode analogue of ``_admit_mixed`` (task fairness holds
        *within* each tier)."""
        interleave = bool(self.slo and self.slo.chunk_interleave)
        admitted = 0
        progress = True
        while bucket.free_slots and progress and self.rotation:
            progress = False
            for off in range(len(self.rotation)):
                if not bucket.free_slots:
                    break
                t = self.rotation[(self._rr + off) % len(self.rotation)]
                r = self._pop_due(t, self.now(), pred)
                if r is not None:
                    self.finished.extend(bucket.admit(
                        r, self.now(), chunk_interleave=interleave))
                    self._rr = (self._rr + off + 1) % len(self.rotation)
                    admitted += 1
                    progress = True
                    if limit is not None and admitted >= limit:
                        return True
                    break
        return admitted > 0

    def _get_parker(self) -> Optional[SlotParker]:
        if self._parker is None:
            mk = getattr(self.backend, "parker", None)
            if mk is not None:
                self._parker = mk(self.slo.park_compress)
        return self._parker

    def _park_victim(self, bucket) -> bool:
        victim = bucket.pick_victim()
        if victim is None:
            return False
        parked = bucket.park(victim, self._parker)
        self.parked.append(parked)
        self.preemptions += 1
        self.parked_bytes += parked["state"].nbytes
        self.parked_bytes_peak = max(self.parked_bytes_peak,
                                     self.parked_bytes)
        return True

    def _admit_slo(self, bucket) -> bool:
        """Tiered admission: interactive first, then preemption for the
        still-waiting interactive, then FIFO restores of parked requests,
        then batch admission into whatever capacity remains."""
        admitted = self._admit_lap(bucket, _interactive)
        if self.slo.preemption and self._get_parker() is not None:
            while (not bucket.free_slots
                   and len(self.parked) < self.slo.max_parked
                   and self._due_any(self.now(), _interactive)):
                if not self._park_victim(bucket):
                    break
                admitted |= self._admit_lap(bucket, _interactive, limit=1)
        while (bucket.free_slots and self.parked
               and not self._due_any(self.now(), _interactive)):
            parked = self.parked.popleft()
            bucket.restore(parked, self._get_parker())
            self.parked_bytes -= parked["state"].nbytes
            self.restores += 1
            admitted = True
        admitted |= self._admit_lap(bucket, is_preemptible)
        return admitted

    # ------------------------------------------------------------ step

    def step(self) -> bool:
        """One scheduling quantum.  Returns False when nothing was runnable
        (e.g. every remaining arrival is in the future)."""
        now = self.now()
        if self.mixed:
            bucket = self._bucket(None)
            admit = self._admit_slo if self.slo is not None \
                else self._admit_mixed
            admitted = admit(bucket)
            if bucket.active == 0 and not admitted and not bucket.jobs:
                return False
            self.finished.extend(bucket.run_quantum(
                self.quantum, self.now,
                admit_cb=lambda: admit(bucket)))
            return True
        # per-task buckets: with an SLO policy, tasks holding a due
        # interactive request take the quantum first
        offsets = list(range(len(self.rotation)))
        if self.slo is not None:
            urgent = [o for o in offsets if self._task_due(
                self.rotation[(self._rr + o) % len(self.rotation)],
                now, _interactive)]
            rest = [o for o in offsets if o not in urgent]
            offsets = urgent + rest
        for off in offsets:
            task = self.rotation[(self._rr + off) % len(self.rotation)]
            if self._runnable(task, now):
                self._rr = (self._rr + off + 1) % len(self.rotation)
                bucket = self._bucket(task)
                q = self.queues[task]

                def admit():
                    if self.slo is None:
                        while bucket.free_slots and q \
                                and q[0].arrival <= self.now():
                            done = bucket.admit(q.popleft(), self.now())
                            self.finished.extend(done)
                        return
                    # tiered: interactive first, then batch
                    while bucket.free_slots:
                        r = self._pop_due(task, self.now(), _interactive) \
                            or self._pop_due(task, self.now(),
                                             is_preemptible)
                        if r is None:
                            break
                        self.finished.extend(bucket.admit(r, self.now()))
                    # stateless "preemption": bump a staged batch-tier
                    # request back to the queue to seat a due interactive
                    bump = getattr(bucket, "bump_batch", None)
                    if bump is None or not self.slo.preemption:
                        return
                    while not bucket.free_slots and self._task_due(
                            task, self.now(), _interactive):
                        bumped = bump()
                        if bumped is None:
                            break
                        self.queues[task].appendleft(bumped)
                        self.preemptions += 1
                        r = self._pop_due(task, self.now(), _interactive)
                        if r is None:
                            break
                        self.finished.extend(bucket.admit(r, self.now()))

                admit()
                # router lookahead across buckets: submit the NEXT task's
                # usage-hot experts before this quantum launches, so their
                # copies ride behind its compute.  The current task's own
                # prefetch runs inside run_quantum AFTER this, so where the
                # two sets conflict the current task wins the slots.
                la = getattr(self.backend, "lookahead", None)
                if la is not None:
                    nxt = self._peek_next_task(task, now)
                    if nxt is not None:
                        la(nxt)
                self.finished.extend(bucket.run_quantum(
                    self.quantum, self.now, admit_cb=admit))
                return True
        return False

    def run(self, requests=None) -> list[Request]:
        """Submit ``requests`` (optional) and drain everything.  Spins (with
        a tiny sleep) while all remaining arrivals are in the future —
        open-loop driving."""
        for r in requests or ():
            self.submit(r)
        self.now()                     # start the clock
        while self.pending():
            if not self.step():
                time.sleep(0.0005)
        return self.finished

    # ------------------------------------------------------------ metrics

    def metrics(self) -> dict[str, Any]:
        done = [r for r in self.finished if r.t_done is not None]
        toks = sum(len(r.tokens) for r in done)
        items = len(done)
        span = max((r.t_done for r in done), default=0.0) - \
            min((r.arrival for r in done), default=0.0)
        # unfinished requests report nan ttft/latency — filter, and guard
        # every percentile against an empty sample (an empty ``done`` used
        # to crash here; a half-finished one used to skew the tail)
        lat = np.array([r.latency for r in done], np.float64)
        lat = lat[np.isfinite(lat)]
        ttft = np.array([r.ttft for r in done], np.float64)
        ttft = ttft[np.isfinite(ttft)]

        def pct(a, p):
            return float(np.percentile(a, p)) if a.size else 0.0

        out: dict[str, Any] = {
            "requests": items,
            "tokens": toks,
            "span_s": span,
            "tok_per_s": toks / span if span > 0 else 0.0,
            "items_per_s": items / span if span > 0 else 0.0,
            "latency_p50_s": pct(lat, 50),
            "latency_p99_s": pct(lat, 99),
            "ttft_p50_s": pct(ttft, 50),
            "ttft_p99_s": pct(ttft, 99),
            "per_task": {
                t: sum(1 for r in done if r.task_id == t)
                for t in self.rotation
            },
        }
        # goodput-under-SLO + per-tier tails (requests without deadlines
        # count as met, so these reduce to throughput when SLOs are unset)
        out.update(goodput(done, span))
        tiers: dict[str, Any] = {}
        for name in sorted({r.tier for r in done}):
            rs = [r for r in done if r.tier == name]
            tt = np.array([r.ttft for r in rs], np.float64)
            tt = tt[np.isfinite(tt)]
            tp = np.array([request_tpot(r) for r in rs], np.float64)
            tp = tp[np.isfinite(tp)]
            tiers[name] = {
                "requests": len(rs),
                "ttft_p50_s": pct(tt, 50),
                "ttft_p99_s": pct(tt, 99),
                "tpot_p50_s": pct(tp, 50),
                "slo_attainment": sum(meets_slo(r) for r in rs) / len(rs),
                "preemptions": sum(r.preemptions for r in rs),
            }
        out["tiers"] = tiers
        if self.slo is not None:
            out["preemptions"] = self.preemptions
            out["restores"] = self.restores
            out["parked_now"] = len(self.parked)
            out["parked_bytes_peak"] = self.parked_bytes_peak
        prefix = getattr(self.backend, "prefix", None)
        if prefix is not None:
            out["prefix_cache"] = prefix.stats()
        chunks = sum(getattr(b, "prefill_chunks", 0)
                     for b in self.buckets.values())
        if chunks:
            out["prefill_chunks"] = chunks
        usage = getattr(self.backend, "usage", None)
        if usage is not None:
            out["expert_usage_task_overlap"] = usage.task_overlap()
        slot_steps = sum(getattr(b, "slot_steps", 0)
                         for b in self.buckets.values())
        steps = sum(getattr(b, "steps", 0) for b in self.buckets.values())
        cap = self.slots_per_bucket
        if steps:
            out["slot_utilization"] = slot_steps / (steps * cap)
        cache_stats = getattr(self.backend, "cache_stats", None)
        if callable(cache_stats):
            cs = cache_stats()
            out["expert_cache"] = cs
            # expert-parallel backends report placement evidence (plan
            # generation, migration/replication events, per-shard load) —
            # surface it top-level so serving reports and benchmark
            # artifacts need not dig through the cache blob
            if "placement" in cs:
                out["placement"] = cs["placement"]
                out["shard_load"] = cs.get("shard_load")
                out["shard_load_imbalance"] = cs.get("shard_load_imbalance")
        return out
