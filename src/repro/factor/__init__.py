"""``repro.factor`` — factored experts: shared basis + per-expert delta.

Storage format (:class:`FactoredTensor`: dense shared basis + low-rank or
Monarch-butterfly per-expert delta, optionally int8/int4-quantized) and the
SVD-seeded offline converters (:func:`factorize` / :func:`factorize_tree`,
accepting dense or QTensor checkpoints).  The compute side lives in
``repro.ops.impls`` as the ``"xla_factored"`` registry implementations
(one basis GEMM shared by the whole wave + per-expert delta correction),
selected via ``ops.policy_named("xla_factored")``; the paging side in
``serve/expert_cache.py``, which pins the basis on device and pages only
the delta leaves — 10-100× more experts per byte of ``budget_bytes``.
"""

from repro.factor.factored import (FACTOR_PARAM_NAMES, FactoredTensor,
                                   factored_linear, factored_moe_gemm,
                                   factorize, factorize_tree, is_factored,
                                   reconstruct, reconstruct_tree, split_dim)

__all__ = [
    "FACTOR_PARAM_NAMES", "FactoredTensor", "factored_linear",
    "factored_moe_gemm", "factorize", "factorize_tree", "is_factored",
    "reconstruct", "reconstruct_tree", "split_dim",
]
