"""FactoredTensor: shared dense basis + tiny per-expert delta.

PR 4's QTensors shrink the bytes an expert pages by ~4-8×; this module is
the next order of magnitude (ROADMAP item 3, the ButterflyViT direction).
The observation is structural: fine-tuned / per-task experts are small
perturbations of a common function, so a bank of E expert weights
``W_e (K, N)`` decomposes as

    W_e  ≈  B  +  Δ_e

where the **basis** ``B (K, N)`` is shared across every expert (device-
resident ONCE, never paged) and the per-expert **delta** ``Δ_e`` is tiny:

  * ``kind="rank"``       — ``Δ_e = U_e @ V_e`` with ``U_e (K, r)``,
    ``V_e (r, N)``: ``r·(K+N)`` numbers instead of ``K·N`` (19× at M³ViT's
    192×768 shapes with r=8).  Seeded by the truncated SVD of the residual
    ``W_e − B`` — the optimal rank-r approximation in Frobenius norm.
  * ``kind="butterfly"``  — a Monarch-style product of two block-diagonal
    factors: with ``K = K1·K2`` and ``N = N1·N2``,
    ``Δ_e[(k1,k2),(n1,n2)] = L_e[k1,k2,n2] · R_e[n2,k1,n1]`` —
    ``K·N2 + N2·K1·N1`` numbers (~9× at M³ViT shapes), applied as two
    small batched GEMMs (never materialized).  Seeded by the exact Monarch
    projection: each ``(k1,n2)`` slice of the residual is a ``(K2, N1)``
    matrix whose best product factor is its rank-1 SVD.

Either delta composes with PR 4's quantization (CoQMoE-style co-design):
``delta_bits=8/4`` stores ``U/V`` (or ``L/R``) as nested :class:`QTensor`
children, so the paged bytes shrink multiplicatively and checkpoints name
the leaves ``<param>.u.q`` / ``<param>.u.scale`` automatically.

``FactoredTensor`` mirrors ``QTensor`` exactly: a registered
pytree-with-keys (checkpoint leaves ``<param>.basis`` / ``.u`` / ``.v``),
it flows through ``jax.jit``, vmap closures, ``device_put`` and
``checkpoint.save/restore`` unchanged.  The compute side lives in
``repro.ops.impls`` as the ``"xla_factored"`` impls (one basis GEMM shared
by the whole wave + the per-expert delta correction); the paging side in
``serve/expert_cache.py``, which pins the basis and pages only the delta
leaves.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant import dequantize, is_qtensor, quantize

__all__ = [
    "FactoredTensor", "is_factored", "factorize", "reconstruct",
    "factorize_tree", "reconstruct_tree", "factored_linear",
    "factored_moe_gemm", "FACTOR_PARAM_NAMES", "split_dim",
]

_KINDS = ("rank", "butterfly")


@jax.tree_util.register_pytree_with_keys_class
class FactoredTensor:
    """Shared basis + per-expert delta factors as one pytree leaf group.

    ``basis`` (K, N) is the dense shared weight; ``u``/``v`` are the delta
    factors — per-expert (leading E axis) or single (no E axis):

      * ``kind="rank"``:      ``u (E?, K, r)``, ``v (E?, r, N)``
      * ``kind="butterfly"``: ``u (E?, K1, K2, N2)``, ``v (E?, N2, K1, N1)``

    ``u``/``v`` may be nested :class:`QTensor` children (int8/int4 delta
    storage).  ``kind`` and the logical compute dtype string are static aux
    data; everything shape-like is derived, so the same class serves jit
    tracers and host arrays.
    """

    __slots__ = ("basis", "u", "v", "kind", "dtype")

    def __init__(self, basis, u, v, *, kind: str = "rank",
                 dtype: str = "float32"):
        self.basis = basis
        self.u = u
        self.v = v
        self.kind = str(kind)
        self.dtype = str(dtype)

    # ------------------------------------------------------------- pytree

    def tree_flatten_with_keys(self):
        return (((jax.tree_util.GetAttrKey("basis"), self.basis),
                 (jax.tree_util.GetAttrKey("u"), self.u),
                 (jax.tree_util.GetAttrKey("v"), self.v)),
                (self.kind, self.dtype))

    @classmethod
    def tree_unflatten(cls, aux, children):
        kind, dtype = aux
        basis, u, v = children
        return cls(basis, u, v, kind=kind, dtype=dtype)

    # ------------------------------------------------------------ queries

    @property
    def experts(self) -> Optional[int]:
        """Expert count (leading delta axis), or None for a single weight."""
        per_expert_ndim = 2 if self.kind == "rank" else 3
        u_shape = tuple(self.u.shape)   # QTensor.shape is the logical shape
        return int(u_shape[0]) if len(u_shape) == per_expert_ndim + 1 \
            else None

    @property
    def rank(self) -> int:
        """Delta rank (``kind="rank"`` only; 0 = pure basis)."""
        return int(tuple(self.u.shape)[-1]) if self.kind == "rank" else -1

    @property
    def shape(self) -> tuple:
        """Logical (reconstructed) shape: (E, K, N) or (K, N)."""
        kn = tuple(self.basis.shape)
        e = self.experts
        return ((e,) + kn) if e is not None else kn

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def basis_nbytes(self) -> int:
        """Bytes resident ONCE regardless of expert count (never paged)."""
        return int(self.basis.nbytes)

    @property
    def delta_nbytes(self) -> int:
        """Bytes that scale with E — the unit the expert cache pages."""
        return int(self.u.nbytes) + int(self.v.nbytes)

    @property
    def nbytes(self) -> int:
        return self.basis_nbytes + self.delta_nbytes

    def __repr__(self) -> str:
        return (f"FactoredTensor({self.kind}, shape={self.shape}, "
                f"dtype={self.dtype}, basis={self.basis_nbytes}B, "
                f"delta={self.delta_nbytes}B)")


def is_factored(x: Any) -> bool:
    return isinstance(x, FactoredTensor)


# ------------------------------------------------------------------ helpers


def split_dim(n: int) -> tuple[int, int]:
    """Butterfly block split: the most-square factorization ``n = a·b``
    with ``a <= b`` (a = largest divisor <= sqrt(n); a=1 for primes —
    degenerate but valid)."""
    n = int(n)
    if n < 1:
        raise ValueError(f"cannot split non-positive dim {n}")
    a = 1
    for d in range(int(np.sqrt(n)), 0, -1):
        if n % d == 0:
            a = d
            break
    return a, n // a


def _check_finite(w, what: str) -> None:
    if isinstance(w, jax.core.Tracer):
        return
    arr = np.asarray(w, np.float32)
    if not np.isfinite(arr).all():
        raise ValueError(
            f"factorize: {what} contains NaN/Inf — a non-finite value "
            "poisons the SVD seeding (every singular vector goes NaN) and "
            "would silently zero the reconstruction; clean the weights "
            "first")


def _leaf_f(leaf, acc):
    """Delta factor -> fp array in ``acc`` (dequantizes nested QTensors)."""
    return dequantize(leaf, acc) if is_qtensor(leaf) else leaf.astype(acc)


def _quantize_delta(leaf, bits: int):
    """Quantize one delta factor; zero-size factors (rank 0) stay raw —
    there is nothing to scale and an empty amax reduction is an error."""
    if leaf.size == 0:
        return leaf
    return quantize(leaf, bits)


# ---------------------------------------------------------------- factorize


def _factorize_rank(resid: np.ndarray, rank: int):
    """Truncated SVD of each expert's residual: the Frobenius-optimal
    rank-r delta.  ``resid (E, K, N)`` -> ``u (E, K, r)``, ``v (E, r, N)``
    with the singular values split evenly (``u·sqrt(s)``, ``sqrt(s)·v``)
    so neither factor carries the full dynamic range."""
    e, k, n = resid.shape
    r = max(0, min(int(rank), k, n))
    if r == 0:
        return (np.zeros((e, k, 0), np.float32),
                np.zeros((e, 0, n), np.float32))
    uu, ss, vt = np.linalg.svd(resid.astype(np.float64),
                               full_matrices=False)
    sq = np.sqrt(ss[:, :r])
    u = (uu[:, :, :r] * sq[:, None, :]).astype(np.float32)
    v = (sq[:, :, None] * vt[:, :r, :]).astype(np.float32)
    return u, v


def _factorize_butterfly(resid: np.ndarray):
    """Exact Monarch projection of each expert's residual.

    Reshape ``(E, K, N) -> (E, K1, K2, N1, N2)``; every ``(k1, n2)`` slice
    is a ``(K2, N1)`` matrix whose Monarch representation is the product of
    one column of ``L`` and one row of ``R`` — i.e. a rank-1 factor.  Its
    best rank-1 approximation is the leading SVD component, so the seeding
    is optimal per slice (and EXACT when the residual is Monarch-
    structured: a rank-1 matrix's top component reproduces it bit-for-bit
    up to fp rounding)."""
    e, k, n = resid.shape
    k1, k2 = split_dim(k)
    n1, n2 = split_dim(n)
    # (E, K1, K2, N1, N2) -> slices (E, K1, N2, K2, N1)
    m = resid.astype(np.float64).reshape(e, k1, k2, n1, n2)
    m = m.transpose(0, 1, 4, 2, 3)
    uu, ss, vt = np.linalg.svd(m, full_matrices=False)
    s0 = np.sqrt(ss[..., 0])                           # (E, K1, N2)
    left = uu[..., :, 0] * s0[..., None]               # (E, K1, N2, K2)
    right = s0[..., None] * vt[..., 0, :]              # (E, K1, N2, N1)
    l_fac = left.transpose(0, 1, 3, 2).astype(np.float32)   # (E,K1,K2,N2)
    r_fac = right.transpose(0, 2, 1, 3).astype(np.float32)  # (E,N2,K1,N1)
    return l_fac, r_fac


def factorize(w, kind: str = "rank", *, rank: int = 8, basis=None,
              delta_bits: Optional[int] = None,
              dtype: Optional[str] = None) -> FactoredTensor:
    """Offline converter: dense (or QTensor) expert weights -> shared basis
    + SVD-seeded per-expert delta.

    ``w``: ``(E, K, N)`` stacked expert weights (the usual case), or a
    single ``(K, N)`` weight with an explicit ``basis`` to delta against.
    ``basis`` defaults to the mean over experts — the centroid minimizes
    the total residual energy the deltas must absorb.
    ``rank``: delta rank for ``kind="rank"`` (0 = pure basis, exact only
    when all experts equal the basis).  Ignored for butterfly.
    ``delta_bits``: 8/4 stores the delta factors as nested QTensors.

    Rejects non-finite inputs (offline converter semantics, like
    ``quant.quantize``).
    """
    if kind not in _KINDS:
        raise ValueError(f"kind must be one of {_KINDS}, got {kind!r}")
    if int(rank) < 0:
        raise ValueError(f"rank must be >= 0, got {rank}")
    if is_qtensor(w):
        w = dequantize(w)
    if getattr(w, "ndim", 0) not in (2, 3):
        raise ValueError(f"factorize expects (E, K, N) stacked experts or "
                         f"a (K, N) weight, got shape "
                         f"{getattr(w, 'shape', ())}")
    _check_finite(w, "weights")
    ldtype = dtype or str(jnp.asarray(w).dtype)
    single = (w.ndim == 2)
    wf = np.asarray(w, np.float32)
    if single:
        wf = wf[None]
    if basis is None:
        if single:
            raise ValueError("factorize of a single (K, N) weight needs an "
                             "explicit basis to delta against")
        b = wf.mean(axis=0)
    else:
        if is_qtensor(basis):
            basis = dequantize(basis)
        _check_finite(basis, "basis")
        b = np.asarray(basis, np.float32)
        if b.shape != wf.shape[1:]:
            raise ValueError(f"basis shape {b.shape} != weight shape "
                             f"{wf.shape[1:]}")
    resid = wf - b[None]
    if kind == "rank":
        u, v = _factorize_rank(resid, rank)
    else:
        u, v = _factorize_butterfly(resid)
    if single:
        u, v = u[0], v[0]
    u, v = jnp.asarray(u), jnp.asarray(v)
    if delta_bits is not None:
        if delta_bits not in (8, 4):
            raise ValueError(f"delta_bits must be 8 or 4, got {delta_bits}")
        u = _quantize_delta(u, delta_bits)
        v = _quantize_delta(v, delta_bits)
    return FactoredTensor(jnp.asarray(b), u, v, kind=kind, dtype=ldtype)


# -------------------------------------------------------------- reconstruct


def _monarch_dense(l_fac, r_fac):
    """(E?, K1, K2, N2) x (E?, N2, K1, N1) -> dense (E?, K, N):
    ``W[(k1,k2),(n1,n2)] = L[k1,k2,n2] * R[n2,k1,n1]``."""
    d = jnp.einsum("...akn,...nab->...akbn", l_fac, r_fac)
    k = d.shape[-4] * d.shape[-3]
    n = d.shape[-2] * d.shape[-1]
    return d.reshape(d.shape[:-4] + (k, n))


def reconstruct(ft: FactoredTensor, dtype=None) -> jax.Array:
    """FactoredTensor -> dense ``(E, K, N)`` (or ``(K, N)``) array in
    ``dtype`` (default: the logical dtype).  Lossy only through the
    factorization itself — a rank-0 delta reconstructs the broadcast basis
    exactly."""
    acc = jnp.float32
    b = ft.basis.astype(acc)
    u, v = _leaf_f(ft.u, acc), _leaf_f(ft.v, acc)
    if ft.kind == "rank":
        delta = jnp.einsum("...kr,...rn->...kn", u, v)
    else:
        delta = _monarch_dense(u, v)
    if ft.experts is not None:
        b = b[None]
    return (b + delta).astype(dtype or ft.dtype)


# ------------------------------------------------------------ apply helpers
#
# The compute forms the "xla_factored" registry impls dispatch to.  The
# basis GEMM contracts only the feature axis, so the per-element summation
# order is independent of the leading expert/slot count — the property that
# makes the paged waves bit-exact with the all-resident forward no matter
# how many slot rows the cache rebuilt the FactoredTensor from.


def factored_moe_gemm(buf, ft: FactoredTensor, acc) -> jax.Array:
    """(E, C, K) x factored (E, K, N) -> (E, C, N) in ``acc``.

    One shared basis GEMM serves the whole wave; the delta correction is
    two skinny batched GEMMs.  int8 rank ``u`` keeps the per-channel
    dequant-epilogue form (scale constant along the contraction axis);
    ``v`` — and everything else — dequantizes before its GEMM (weights-only
    compression: the memory multiplier is the point, the MACs stay fp).
    ``v``'s epilogue would sit inside the final ``y + delta`` add, and XLA
    contracts ``add(y, mul(dot, scale))`` into an FMA under jit — one
    rounding instead of two — which would break the paged-vs-direct
    bit-exactness contract (the paged wave runs jitted, the direct path
    may not); the dequant-before-GEMM form ends on a dot, which never
    FMA-fuses with the outer add."""
    xb = buf.astype(acc)
    y = jnp.einsum("ecd,df->ecf", xb, ft.basis.astype(acc),
                   preferred_element_type=acc)
    if ft.kind == "rank":
        if ft.rank == 0:
            return y
        u, v = ft.u, ft.v
        if is_qtensor(u) and u.bits == 8:
            t = jnp.einsum("ecd,edr->ecr", xb, u.q.astype(acc),
                           preferred_element_type=acc) * u.scale.astype(acc)
        else:
            t = jnp.einsum("ecd,edr->ecr", xb, _leaf_f(u, acc),
                           preferred_element_type=acc)
        return y + jnp.einsum("ecr,erf->ecf", t, _leaf_f(v, acc),
                              preferred_element_type=acc)
    l_fac, r_fac = _leaf_f(ft.u, acc), _leaf_f(ft.v, acc)
    _, k1, k2, _ = l_fac.shape
    xr = xb.reshape(xb.shape[:-1] + (k1, k2))
    t = jnp.einsum("ecak,eakn->ecan", xr, l_fac,
                   preferred_element_type=acc)
    z = jnp.einsum("ecan,enab->ecbn", t, r_fac,
                   preferred_element_type=acc)
    return y + z.reshape(z.shape[:2] + (-1,))


def factored_linear(x, ft: FactoredTensor, acc) -> jax.Array:
    """(..., K) x factored single (K, N) -> (..., N) in ``acc``."""
    xb = x.astype(acc)
    y = jnp.matmul(xb, ft.basis.astype(acc), preferred_element_type=acc)
    if ft.kind == "rank":
        if ft.rank == 0:
            return y
        u, v = ft.u, ft.v
        if is_qtensor(u) and u.bits == 8:
            t = jnp.matmul(xb, u.q.astype(acc),
                           preferred_element_type=acc) * u.scale.astype(acc)
        else:
            t = jnp.matmul(xb, _leaf_f(u, acc), preferred_element_type=acc)
        # v dequantizes before its GEMM (see factored_moe_gemm: the
        # epilogue form would FMA-fuse into the outer add under jit)
        return y + jnp.matmul(t, _leaf_f(v, acc),
                              preferred_element_type=acc)
    l_fac, r_fac = _leaf_f(ft.u, acc), _leaf_f(ft.v, acc)
    k1, k2, _ = l_fac.shape
    xr = xb.reshape(xb.shape[:-1] + (k1, k2))
    t = jnp.einsum("...ak,akn->...an", xr, l_fac,
                   preferred_element_type=acc)
    z = jnp.einsum("...an,nab->...bn", t, r_fac,
                   preferred_element_type=acc)
    return y + z.reshape(z.shape[:-2] + (-1,))


# -------------------------------------------------------------------- trees

# Per-expert stacked (leading E axis, ndim == 3) FFN weights — the set the
# serving layer pages and therefore the set worth factoring.  Gates and
# biases are absent: gates route (never paged per expert as weights worth
# compressing) and biases are O(d) — paging them dense is cheaper than any
# factor bookkeeping.
FACTOR_PARAM_NAMES = frozenset({"wg", "wu", "wd", "w1", "w2"})


def _factorable(name: str, leaf, names) -> bool:
    if name not in names or is_factored(leaf):
        return False
    if is_qtensor(leaf):
        return len(leaf.shape) == 3
    return (isinstance(leaf, (np.ndarray, jax.Array))
            and getattr(leaf, "ndim", 0) == 3
            and jnp.issubdtype(leaf.dtype, jnp.floating))


def factorize_tree(tree, kind: str = "rank", *, rank: int = 8,
                   delta_bits: Optional[int] = None,
                   names=FACTOR_PARAM_NAMES):
    """Offline converter: replace every stacked-expert weight leaf (dict
    key in ``names``, ndim == 3, floating or QTensor, sitting NEXT TO a
    ``"gate"`` sibling) with a :class:`FactoredTensor`.

    The gate sibling is the structural marker of an expert dict — it is
    what distinguishes a stacked-EXPERT ``(E, K, N)`` weight from a
    layer-stacked dense-block ``(L, K, N)`` weight of the same name and
    rank (shapes alone cannot: a ViT trunk's scanned dense MLPs look
    exactly like an expert stack).  Averaging *layers* into a basis would
    be semantically wrong, and per-layer slicing of a factored leaf would
    shred the basis; routed experts always live beside their router, so
    the sibling test is both necessary and cheap.  Everything else —
    gates, biases, norms, dense-block MLPs, scanned LM stacks (ndim 4) —
    passes through untouched."""
    def walk(node):
        if isinstance(node, dict):
            is_expert_dict = "gate" in node
            return {k: (factorize(v, kind, rank=rank, delta_bits=delta_bits)
                        if is_expert_dict and _factorable(k, v, names)
                        else walk(v))
                    for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        return node
    return walk(tree)


def reconstruct_tree(tree):
    """Inverse of :func:`factorize_tree` (lossy: returns the reconstructed
    dense weights in their logical dtype)."""
    return jax.tree.map(
        lambda x: reconstruct(x) if is_factored(x) else x, tree,
        is_leaf=is_factored)
