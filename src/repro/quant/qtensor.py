"""QTensor: packed low-precision weights + scales as one pytree leaf group.

Edge-MoE's memory story is experts-per-byte: the DDR expert stream (§IV-D)
moves whole expert weight tensors, so shrinking the bytes per expert
multiplies both the resident-expert count at a fixed device budget and the
effective paging bandwidth.  A :class:`QTensor` is the storage format that
realizes this on the TPU side:

  * **int8, per-channel** — symmetric quantization along the *contraction*
    axis (axis ``-2`` of a ``(..., K, N)`` weight): one f32 scale per output
    channel, ``w ≈ q * scale`` with ``scale`` broadcastable against ``q``.
    Because the scale is constant along K, dequantization commutes with the
    GEMM and becomes a per-column epilogue: ``x @ w ≈ (x @ q) * scale`` —
    the "dequant-in-kernel" form the ``xla_int8`` registry impls use.
  * **int4, grouped** — symmetric ±7 quantization with one scale per
    ``group_size`` rows of K per output channel; two values are packed per
    byte along K.  The scale varies along the contraction axis, so int4
    dequantizes *before* the GEMM (weights-only compression: the memory
    multiplier is the point, the MACs stay fp).

``QTensor`` is a registered pytree (with key paths, so checkpoints name its
leaves ``<param>.q`` / ``<param>.scale``): it flows through ``jax.jit``,
``vmap`` closures, device_put, and ``checkpoint.save/restore`` like any
other params leaf.  The int8 payload round-trips checkpoints bit-exactly.

The KV-cache variant (:func:`quantize_kv`) is per-token-per-head — one
scale per written cache row — and is jit-safe (no host checks), since it
runs inside the decode step.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "QTensor", "is_qtensor", "quantize", "dequantize", "quantize_kv",
    "quantize_tree", "dequantize_tree", "tree_bytes", "QUANT_PARAM_NAMES",
]

_TINY = float(np.finfo(np.float32).tiny)


@jax.tree_util.register_pytree_with_keys_class
class QTensor:
    """Packed values + scales.  ``q``/``scale`` are the dynamic children;
    ``bits`` (8 | 4), ``dtype`` (logical compute dtype string) and ``rows``
    (logical size of the contraction axis; None for int8, where it equals
    ``q.shape[-2]``) are static aux data.
    """

    __slots__ = ("q", "scale", "bits", "dtype", "rows")

    def __init__(self, q, scale, *, bits: int = 8, dtype: str = "float32",
                 rows: Optional[int] = None):
        self.q = q
        self.scale = scale
        self.bits = int(bits)
        self.dtype = str(dtype)
        self.rows = None if rows is None else int(rows)

    # ------------------------------------------------------------- pytree

    def tree_flatten_with_keys(self):
        return (((jax.tree_util.GetAttrKey("q"), self.q),
                 (jax.tree_util.GetAttrKey("scale"), self.scale)),
                (self.bits, self.dtype, self.rows))

    @classmethod
    def tree_unflatten(cls, aux, children):
        bits, dtype, rows = aux
        q, scale = children
        return cls(q, scale, bits=bits, dtype=dtype, rows=rows)

    # ------------------------------------------------------------ queries

    @property
    def shape(self) -> tuple:
        """Logical (dequantized) shape."""
        s = tuple(self.q.shape)
        if self.bits == 4:
            rows = self.rows if self.rows is not None else 2 * s[-2]
            return s[:-2] + (rows,) + s[-1:]
        return s

    @property
    def ndim(self) -> int:
        return len(self.q.shape)

    @property
    def nbytes(self) -> int:
        return int(self.q.nbytes) + int(self.scale.nbytes)

    def __repr__(self) -> str:
        return (f"QTensor(int{self.bits}, shape={self.shape}, "
                f"dtype={self.dtype}, nbytes={self.nbytes})")


def is_qtensor(x: Any) -> bool:
    return isinstance(x, QTensor)


# ---------------------------------------------------------------- quantize


def _check_finite(w) -> None:
    if isinstance(w, jax.core.Tracer):
        return
    arr = np.asarray(w, np.float32)
    if not np.isfinite(arr).all():
        raise ValueError(
            "quantize: input contains NaN/Inf — a non-finite value would "
            "poison the channel scale (amax) and silently zero the whole "
            "channel; clean the weights first")


def _quantize_int8(w: jax.Array, dtype: str) -> QTensor:
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2, keepdims=True)
    scale = jnp.maximum(amax / 127.0, _TINY)       # scale > 0 always
    q = jnp.clip(jnp.round(wf / scale), -127, 127).astype(jnp.int8)
    return QTensor(q, scale, bits=8, dtype=dtype)


def _quantize_int4(w: jax.Array, group_size: int, dtype: str) -> QTensor:
    rows = w.shape[-2]
    g = max(2, min(int(group_size), rows))
    g += g % 2                                     # even: packing pairs rows
    pad = (-rows) % g
    wf = w.astype(jnp.float32)
    if pad:
        widths = [(0, 0)] * w.ndim
        widths[-2] = (0, pad)
        wf = jnp.pad(wf, widths)
    kp = rows + pad
    lead = wf.shape[:-2]
    n = wf.shape[-1]
    grouped = wf.reshape(lead + (kp // g, g, n))
    amax = jnp.max(jnp.abs(grouped), axis=-2)      # (..., K/g, N)
    scale = jnp.maximum(amax / 7.0, _TINY)
    q = jnp.clip(jnp.round(grouped / scale[..., None, :]), -7, 7)
    q = q.reshape(lead + (kp, n)).astype(jnp.int8)
    lo = (q[..., 0::2, :] & 0xF).astype(jnp.uint8)
    hi = (q[..., 1::2, :] & 0xF).astype(jnp.uint8)
    packed = lo | (hi << 4)
    return QTensor(packed, scale, bits=4, dtype=dtype, rows=rows)


def quantize(w, bits: int = 8, *, group_size: int = 32,
             dtype: Optional[str] = None) -> QTensor:
    """Quantize a weight ``(..., K, N)`` along the contraction axis.

    ``bits=8``: per-channel symmetric int8, scale ``(..., 1, N)``.
    ``bits=4``: grouped symmetric int4 (±7), ``group_size`` rows per scale,
    packed two values per byte along K.

    Rejects non-finite inputs (offline converter semantics — use
    :func:`quantize_kv` for the jit-safe activation path).
    """
    if bits not in (8, 4):
        raise ValueError(f"bits must be 8 or 4, got {bits}")
    if getattr(w, "ndim", 0) < 2:
        raise ValueError(f"quantize expects a (..., K, N) weight, "
                         f"got shape {getattr(w, 'shape', ())}")
    _check_finite(w)
    w = jnp.asarray(w)
    ldtype = dtype or str(w.dtype)
    if bits == 8:
        return _quantize_int8(w, ldtype)
    return _quantize_int4(w, group_size, ldtype)


def dequantize(qt: QTensor, dtype=None) -> jax.Array:
    """QTensor -> dense array in ``dtype`` (default: the logical dtype)."""
    if qt.bits == 8:
        w = qt.q.astype(jnp.float32) * qt.scale
    else:
        packed = qt.q
        lo = (packed & 0xF).astype(jnp.int8)
        hi = (packed >> 4).astype(jnp.int8)
        lo = lo - 16 * (lo >= 8)                  # sign-extend the nibble
        hi = hi - 16 * (hi >= 8)
        lead = packed.shape[:-2]
        n = packed.shape[-1]
        kp = 2 * packed.shape[-2]
        q = jnp.stack([lo, hi], axis=-2)          # (..., K/2, 2, N)
        q = q.reshape(lead + (kp, n)).astype(jnp.float32)
        ng = qt.scale.shape[-2]
        g = kp // ng
        w = (q.reshape(lead + (ng, g, n))
             * qt.scale[..., :, None, :]).reshape(lead + (kp, n))
        rows = qt.rows if qt.rows is not None else kp
        if rows != kp:
            w = w[..., :rows, :]
    return w.astype(dtype or qt.dtype)


# ------------------------------------------------------------------ KV cache


def quantize_kv(x: jax.Array):
    """Per-row (token × head) symmetric int8: ``(..., D)`` ->
    ``(q int8 (..., D), scale f32 (..., 1))``.  jit-safe (no host checks);
    an all-zero row keeps a tiny positive scale and dequantizes to exact
    zeros.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax / 127.0, _TINY)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


# -------------------------------------------------------------------- trees

# Weight names that flow through ``unified_linear`` / ``moe_grouped_gemm``
# dispatch (attention projections, MLPs, MoE experts + shared experts, LM /
# task heads, patch embed, recurrent up/down projections).  Gates, biases,
# norms, embeddings, and convs are deliberately absent: they are either
# consumed by raw einsums/takes or too small to matter.
QUANT_PARAM_NAMES = frozenset({
    "wq", "wk", "wv", "wo", "w", "wg", "wu", "wd", "w1", "w2",
    "shared_wg", "shared_wu", "shared_wd", "w_up", "w_up2", "w_down",
    "w_qkv",
})


def _quantizable(name: str, leaf, names) -> bool:
    # the isinstance guard is structural, not just defensive: non-array
    # leaf groups with array-like duck typing (factor.FactoredTensor has
    # ndim/shape too) must pass through untouched — their delta factors
    # are quantized at factorize(delta_bits=...) time, never re-wrapped
    return (name in names and isinstance(leaf, (np.ndarray, jax.Array))
            and getattr(leaf, "ndim", 0) >= 2
            and jnp.issubdtype(leaf.dtype, jnp.floating))


def quantize_tree(tree, bits: int = 8, *, group_size: int = 32,
                  names=QUANT_PARAM_NAMES):
    """Offline converter: replace every matmul-weight leaf (dict key in
    ``names``, ndim >= 2, floating) with a :class:`QTensor`.  Everything
    else — gates, biases, norms, embeddings — passes through untouched.
    """
    def walk(node):
        if isinstance(node, dict):
            return {k: (quantize(v, bits, group_size=group_size)
                        if _quantizable(k, v, names) else walk(v))
                    for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        return node
    return walk(tree)


def dequantize_tree(tree):
    """Inverse of :func:`quantize_tree` (lossy: returns the dequantized
    weights in their logical dtype)."""
    return jax.tree.map(
        lambda x: dequantize(x) if is_qtensor(x) else x, tree,
        is_leaf=is_qtensor)


def tree_bytes(tree) -> int:
    """Total storage bytes of a params tree (QTensor leaves count packed)."""
    return sum(int(x.nbytes) for x in jax.tree.leaves(tree))
