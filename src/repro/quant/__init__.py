"""``repro.quant`` — int8/int4 weight & KV quantization.

Storage format (:class:`QTensor`), offline converters
(:func:`quantize_tree`), and the jit-safe KV-cache quantizer
(:func:`quantize_kv`).  The compute side lives in ``repro.ops.impls`` as
the ``"xla_int8"`` registry implementations, selected via
``ops.policy_named("xla_int8")``; the paging side in
``serve/expert_cache.py``, which pages packed expert weights so a fixed
device budget holds ~4× (int8) / ~8× (int4) more resident experts.
"""

from repro.quant.qtensor import (QTensor, QUANT_PARAM_NAMES, dequantize,
                                 dequantize_tree, is_qtensor, quantize,
                                 quantize_kv, quantize_tree, tree_bytes)

__all__ = [
    "QTensor", "QUANT_PARAM_NAMES", "dequantize", "dequantize_tree",
    "is_qtensor", "quantize", "quantize_kv", "quantize_tree", "tree_bytes",
]
